//! Spot fleet requests: allocation strategies, weighted capacity,
//! fulfillment latency, interruption, replacement, on-demand base.
//!
//! Reproduced paper behaviours:
//!
//! * "depending on current AWS capacity and the price that you bid, it can
//!   take anywhere from a couple of minutes to several hours for your
//!   machines to be ready" — fulfillment latency grows as the bid
//!   approaches the spot price and collapses to "wait for the next
//!   evaluation" when the pool has no free capacity.
//! * Interruption: any running spot instance whose pool price rises above
//!   its fleet's effective bid (`bid × weight`) is reclaimed.
//! * Replacement: an active fleet relaunches toward its target capacity
//!   whenever instances die (crash reaper, self-shutdown, interruption) —
//!   which is also the paper's cost leak that `monitor` exists to close.
//! * Cheapest mode: `modify_target` lowers the *requested* capacity
//!   without terminating running machines.
//!
//! Beyond the paper's single-type fleet, this module reproduces the full
//! Spot Fleet request surface the paper's `exampleFleet.json` rides on:
//!
//! * **Heterogeneous pools** — a fleet names several instance types
//!   ([`InstanceSlot`]), each a separate capacity pool with its own
//!   independent price walk (see [`super::market`]).
//! * **Weighted capacity** — each slot contributes `weight` units toward
//!   `target_capacity`, and bids are per *unit*, so one bid can be tight
//!   across differently-sized machines.
//! * **[`AllocationStrategy`]** — how the deficit is split across
//!   eligible pools: `LowestPrice` (greedy cheapest-per-unit),
//!   `Diversified` (round-robin across all eligible pools), or
//!   `CapacityOptimized` (deepest pools first, fewest interruptions).
//! * **On-demand base** — the first `on_demand_base` units are bought
//!   on-demand: flat-billed, never interrupted (AWS's
//!   `OnDemandBaseCapacity`).
//!
//! # Example: a diversified heterogeneous fleet
//!
//! ```
//! use ds_rs::aws::ec2::{AllocationStrategy, Ec2, InstanceSlot, SpotFleetSpec,
//!                       SpotMarket, Volatility};
//! use ds_rs::sim::SimRng;
//!
//! let mut ec2 = Ec2::new(SpotMarket::new(7, Volatility::Low), SimRng::new(7));
//! let fleet = ec2.request_spot_fleet(SpotFleetSpec {
//!     target_capacity: 4,
//!     bid_hourly: 0.10,
//!     slots: vec![InstanceSlot::new("m5.large"), InstanceSlot::new("c5.xlarge")],
//!     allocation: AllocationStrategy::Diversified,
//!     on_demand_base: 0,
//! });
//! ec2.evaluate_fleets(0);
//! // Diversified splits the four units across both pools, two each.
//! assert_eq!(ec2.active_weight(fleet), 4);
//! let types: Vec<&str> = ec2.all_instances().iter().map(|i| i.itype.name).collect();
//! assert_eq!(types.iter().filter(|t| *t == "m5.large").count(), 2);
//! assert_eq!(types.iter().filter(|t| *t == "c5.xlarge").count(), 2);
//! ```

use std::collections::{BTreeMap, HashMap};

use crate::sim::clock::{SimTime, SECOND};
use crate::sim::store::{IdStore, StoreKind};
use crate::sim::SimRng;
use crate::topology::Placement;

use super::instance::{Instance, InstanceId, InstanceState, Lifecycle, TerminationReason};
use super::market::SpotMarket;
use super::pricing::instance_type;

/// Fleet request identifier (`sfr-0007`).
pub type FleetId = u64;

/// How a fleet's capacity deficit is split across eligible capacity
/// pools.  Mirrors AWS Spot Fleet's `AllocationStrategy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum AllocationStrategy {
    /// Fill greedily from the pool with the lowest per-unit price.
    /// Cheapest now; concentrated, so one pool spike can take the whole
    /// fleet at once.
    #[default]
    LowestPrice,
    /// Round-robin one instance at a time across every eligible pool.
    /// Spreads interruption risk: a spike in one pool costs only that
    /// pool's share.
    Diversified,
    /// Fill greedily from the pool with the most free capacity (ties:
    /// cheaper per-unit first).  Deep pools spike less often than
    /// drained ones.
    CapacityOptimized,
}

impl AllocationStrategy {
    /// All strategies, in a stable order (sweep axes iterate this).
    pub const ALL: [AllocationStrategy; 3] = [
        AllocationStrategy::LowestPrice,
        AllocationStrategy::Diversified,
        AllocationStrategy::CapacityOptimized,
    ];

    /// Stable kebab-case name (config-file and CLI syntax).
    pub fn name(self) -> &'static str {
        match self {
            AllocationStrategy::LowestPrice => "lowest-price",
            AllocationStrategy::Diversified => "diversified",
            AllocationStrategy::CapacityOptimized => "capacity-optimized",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|a| a.name() == s)
    }
}

/// One launch specification inside a fleet: an instance type plus the
/// weighted-capacity units each such instance contributes.
///
/// The config-file / CLI syntax is `"name"` (weight 1) or `"name:weight"`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InstanceSlot {
    pub name: String,
    /// Capacity units per instance (AWS `WeightedCapacity`), >= 1.
    pub weight: u32,
}

impl InstanceSlot {
    /// A weight-1 slot (the paper's original one-machine-one-unit shape).
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            weight: 1,
        }
    }

    /// Parse `"name"` or `"name:weight"`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let (name, weight) = match s.split_once(':') {
            Some((n, w)) => (
                n.trim(),
                w.trim()
                    .parse::<u32>()
                    .map_err(|_| format!("bad weight in instance slot '{s}'"))?,
            ),
            None => (s.trim(), 1),
        };
        if name.is_empty() {
            return Err(format!("empty instance type in slot '{s}'"));
        }
        if weight == 0 {
            return Err(format!("weight must be >= 1 in instance slot '{s}'"));
        }
        Ok(Self {
            name: name.to_string(),
            weight,
        })
    }

    /// Inverse of [`parse`](Self::parse): `"name"` when the weight is 1,
    /// `"name:weight"` otherwise.
    pub fn render(&self) -> String {
        if self.weight == 1 {
            self.name.clone()
        } else {
            format!("{}:{}", self.name, self.weight)
        }
    }
}

/// A spot fleet request: what `startCluster` submits.
#[derive(Debug, Clone, PartialEq)]
pub struct SpotFleetSpec {
    /// CLUSTER_MACHINES from the Config file, in *weighted units* (equal
    /// to machine count when every slot has weight 1).
    pub target_capacity: u32,
    /// MACHINE_PRICE: max USD/h per weighted unit.  An instance's
    /// effective bid is `bid_hourly × slot.weight`.
    pub bid_hourly: f64,
    /// The fleet's launch specifications; each distinct type is one
    /// capacity pool.
    pub slots: Vec<InstanceSlot>,
    /// How the deficit is split across eligible pools.
    pub allocation: AllocationStrategy,
    /// Units (not instances) to keep on-demand: flat-billed, never
    /// interrupted.  Clamped to `target_capacity`.
    pub on_demand_base: u32,
}

impl Default for SpotFleetSpec {
    fn default() -> Self {
        Self {
            target_capacity: 1,
            bid_hourly: 0.10,
            slots: vec![InstanceSlot::new("m5.xlarge")],
            allocation: AllocationStrategy::LowestPrice,
            on_demand_base: 0,
        }
    }
}

impl SpotFleetSpec {
    /// The paper's original shape: one weight-1 instance type, lowest
    /// price, no on-demand base.
    pub fn homogeneous(target_capacity: u32, bid_hourly: f64, type_name: &str) -> Self {
        Self {
            target_capacity,
            bid_hourly,
            slots: vec![InstanceSlot::new(type_name)],
            ..Self::default()
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FleetState {
    Active,
    Cancelled,
}

#[derive(Debug)]
struct Fleet {
    spec: SpotFleetSpec,
    state: FleetState,
}

/// What happened during a fleet evaluation tick.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetEvent {
    /// A new instance was requested; it becomes Running at `ready_at`.
    InstanceRequested {
        id: InstanceId,
        ready_at: SimTime,
        itype: &'static str,
        price: f64,
    },
    /// A running instance was reclaimed (spot price exceeded the bid).
    InstanceInterrupted { id: InstanceId, price: f64 },
    /// Weighted units that could not be fulfilled this tick (no eligible
    /// pool).
    CapacityUnavailable { fleet: FleetId, missing: u32 },
}

/// One billed instance lifetime: written on termination.
#[derive(Debug, Clone, PartialEq)]
pub struct CostRecord {
    pub instance: InstanceId,
    pub itype: &'static str,
    /// Spot records are integrated over the pool's price walk; on-demand
    /// records bill flat at the catalog hourly price.
    pub lifecycle: Lifecycle,
    pub span: (SimTime, SimTime),
    pub cost_usd: f64,
    pub reason: TerminationReason,
    /// Failure domain the instance ran in (0 without a topology).
    pub domain: u32,
}

/// Per-pool slice of a run's fleet activity: launches, interruptions,
/// billed machine-hours and dollars.  On-demand usage of a type is a
/// separate pool labelled `"<type>/on-demand"`.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolBreakdown {
    /// Pool label: the instance type, with `"/on-demand"` appended for
    /// the on-demand slice.
    pub pool: String,
    /// Instances ever launched into this pool.
    pub launched: u64,
    /// Spot interruptions suffered by this pool.
    pub interrupted: u64,
    /// Billed machine-hours (terminated + still-running accrual).
    pub machine_hours: f64,
    /// Billed dollars (terminated + still-running accrual).
    pub cost_usd: f64,
}

impl PoolBreakdown {
    fn empty(pool: String) -> Self {
        Self {
            pool,
            launched: 0,
            interrupted: 0,
            machine_hours: 0.0,
            cost_usd: 0.0,
        }
    }
}

/// Pool label: the instance type (with `"/on-demand"` for the on-demand
/// slice), suffixed `"@<domain>"` only when a topology is installed, so
/// pre-topology labels stay byte-identical.
fn pool_label(itype: &str, lifecycle: Lifecycle, domain: u32, domains: &[String]) -> String {
    let base = match lifecycle {
        Lifecycle::Spot => itype.to_string(),
        Lifecycle::OnDemand => format!("{itype}/on-demand"),
    };
    match domains.get(domain as usize) {
        Some(name) => format!("{base}@{name}"),
        None => base,
    }
}

/// A pool's price per weighted unit.
fn per_unit(price: f64, weight: u32) -> f64 {
    price / f64::from(weight)
}

/// What one billable span costs: the single place the spot-vs-on-demand
/// billing rule lives (spot integrates the pool's price walk; on-demand
/// bills flat at the catalog hourly rate).  Used by termination billing,
/// end-of-run accrual, and the per-pool breakdown.
fn billed_cost(
    market: &mut SpotMarket,
    itype: &'static str,
    od_hourly: f64,
    lifecycle: Lifecycle,
    domain: u32,
    start: SimTime,
    end: SimTime,
) -> f64 {
    match lifecycle {
        Lifecycle::Spot => market.cost_integral_in(domain, itype, start, end),
        Lifecycle::OnDemand => od_hourly * (end - start) as f64 / crate::sim::HOUR as f64,
    }
}

/// One failure domain's share of the fleet activity (the compute half of
/// a `TopologyBreakdown` domain slice; jobs are the coordinator's).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DomainUsage {
    /// Instances ever launched into the domain.
    pub launched: u64,
    /// Correlated + market interruptions (spot reclaims and AZ-outage
    /// kills) suffered in the domain.
    pub interrupted: u64,
    /// Billed dollars (terminated + still-running accrual).
    pub cost_usd: f64,
}

/// The EC2 service: spot market + instances + fleets.
pub struct Ec2 {
    pub market: SpotMarket,
    /// Instance table — dense, id-indexed by default (ids are the
    /// sequential `i-N` tags), so the per-tick interruption/fulfillment
    /// scans walk contiguous memory instead of chasing hash buckets.
    instances: IdStore<Instance>,
    fleets: HashMap<FleetId, Fleet>,
    next_instance: InstanceId,
    next_fleet: FleetId,
    rng: SimRng,
    cost_log: Vec<CostRecord>,
    /// Installed failure-domain names (empty = no topology: every code
    /// path below is bit-identical to the pre-topology fleet).
    domains: Vec<String>,
    /// How spot capacity is distributed over the installed domains.
    placement: Placement,
}

impl Ec2 {
    pub fn new(market: SpotMarket, rng: SimRng) -> Self {
        Self::with_store(market, rng, StoreKind::default())
    }

    /// An EC2 service on an explicit entity-storage backend (the A/B
    /// equivalence gate runs both).
    pub fn with_store(market: SpotMarket, rng: SimRng, kind: StoreKind) -> Self {
        Self {
            market,
            instances: IdStore::with_kind(kind),
            fleets: HashMap::new(),
            next_instance: 0,
            next_fleet: 0,
            rng,
            cost_log: Vec::new(),
            domains: Vec::new(),
            placement: Placement::Pack,
        }
    }

    /// Install a cluster topology: named failure domains (each becoming
    /// an independent set of capacity pools in the market) and the
    /// placement policy that distributes spot capacity over them.  Call
    /// before any fleet activity.
    pub fn install_topology(&mut self, domains: Vec<String>, placement: Placement) {
        self.market.install_domains(domains.len() as u32);
        self.domains = domains;
        self.placement = placement;
    }

    /// Installed failure-domain names (empty without a topology).
    pub fn domains(&self) -> &[String] {
        &self.domains
    }

    /// Active instance ids in failure domain `domain`, sorted (the
    /// AZ-outage kill list).
    pub fn active_in_domain(&self, domain: u32) -> Vec<InstanceId> {
        let mut v: Vec<InstanceId> = self
            .instances
            .values()
            .filter(|i| i.is_active() && i.domain == domain)
            .map(|i| i.id)
            .collect();
        v.sort_unstable();
        v
    }

    /// RequestSpotFleet: returns the fleet id; instances appear on the
    /// next `evaluate_fleets` call.
    pub fn request_spot_fleet(&mut self, spec: SpotFleetSpec) -> FleetId {
        assert!(
            !spec.slots.is_empty(),
            "fleet spec needs at least one instance slot"
        );
        for s in &spec.slots {
            assert!(
                instance_type(&s.name).is_some(),
                "unknown instance type in fleet spec: {}",
                s.name
            );
            assert!(s.weight >= 1, "slot weight must be >= 1: {}", s.name);
        }
        self.next_fleet += 1;
        let id = self.next_fleet;
        self.fleets.insert(
            id,
            Fleet {
                spec,
                state: FleetState::Active,
            },
        );
        id
    }

    /// ModifySpotFleetRequest: change target capacity.  Never terminates
    /// running instances (cheapest mode relies on this).
    pub fn modify_target(&mut self, fleet: FleetId, target: u32) {
        if let Some(f) = self.fleets.get_mut(&fleet) {
            f.spec.target_capacity = target;
        }
    }

    /// Active instances of a fleet ranked most-expensive-per-unit first
    /// (i.e. the cheapest pool comes last), still-booting before running
    /// within a price tie.  Spot instances rank by the pool's current
    /// spot price; on-demand instances by what they actually bill — the
    /// catalog hourly rate.  Tuple: (per-unit price, pending-first rank,
    /// id, weight, is-on-demand).
    fn ranked_scale_in_victims(
        &mut self,
        fleet: FleetId,
        now: SimTime,
    ) -> Vec<(f64, u8, InstanceId, u32, bool)> {
        let mut actives: Vec<(f64, u8, InstanceId, u32, bool)> = Vec::new();
        for inst in self.instances.values() {
            if inst.fleet != fleet || !inst.is_active() {
                continue;
            }
            let hourly = match inst.lifecycle {
                Lifecycle::Spot => self.market.price_at_in(inst.domain, inst.itype.name, now),
                Lifecycle::OnDemand => inst.itype.on_demand_hourly,
            };
            let pending = if inst.state == InstanceState::Pending { 0u8 } else { 1 };
            actives.push((
                per_unit(hourly, inst.weight),
                pending,
                inst.id,
                inst.weight,
                inst.lifecycle == Lifecycle::OnDemand,
            ));
        }
        actives.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap()
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        actives
    }

    /// The fleet's configured on-demand base (0 for unknown fleets).
    fn od_base_of(&self, fleet: FleetId) -> u32 {
        self.fleets
            .get(&fleet)
            .map(|f| f.spec.on_demand_base)
            .unwrap_or(0)
    }

    /// Reduce the fleet to `new_target` weighted units by *terminating*
    /// excess instances, most-expensive-per-unit pool first — i.e. the
    /// cheapest pool is downscaled last.  Still-booting instances in a
    /// pool die before running ones.  Never undershoots the target, and
    /// never terminates an on-demand instance that the effective floor
    /// (`on_demand_base.min(new_target)` — exactly what
    /// `evaluate_fleets` maintains) would immediately rebuy; scaling
    /// *below* the od base therefore does release on-demand capacity.
    /// Returns the terminated ids (reason [`TerminationReason::FleetDownscale`]).
    pub fn scale_in(&mut self, fleet: FleetId, new_target: u32, now: SimTime) -> Vec<InstanceId> {
        self.modify_target(fleet, new_target);
        let od_floor = self.od_base_of(fleet).min(new_target);
        let victims = self.ranked_scale_in_victims(fleet, now);
        let mut aw = self.active_weight(fleet);
        let mut od_w = self.active_weight_of(fleet, Lifecycle::OnDemand);
        let mut killed = Vec::new();
        for (_, _, id, w, is_od) in victims {
            if aw <= new_target {
                break;
            }
            if aw - w < new_target {
                continue; // removing this one would undershoot
            }
            if is_od && od_w.saturating_sub(w) < od_floor {
                continue; // evaluate_fleets would rebuy it next tick
            }
            self.terminate(id, TerminationReason::FleetDownscale, now);
            aw -= w;
            if is_od {
                od_w -= w;
            }
            killed.push(id);
        }
        killed
    }

    /// Like [`scale_in`](Self::scale_in) but the budget is *machines*
    /// rather than weighted units — what a throughput-driven caller (the
    /// monitor's queue-downscale) wants, since a weight-3 machine still
    /// runs only one machine's worth of containers.  Terminates down to
    /// at most `machines` active instances (same ranking as `scale_in`),
    /// then lowers the requested capacity to the surviving weight so
    /// nothing is relaunched.  The full configured `on_demand_base` is
    /// protected here (not clamped): the new target is only known after
    /// the kills, and dropping on-demand weight below the base while
    /// spot survivors keep the total above it would make
    /// `evaluate_fleets` rebuy the difference — churn for nothing.
    pub fn scale_in_to_machines(
        &mut self,
        fleet: FleetId,
        machines: u32,
        now: SimTime,
    ) -> Vec<InstanceId> {
        let od_base = self.od_base_of(fleet);
        let victims = self.ranked_scale_in_victims(fleet, now);
        let mut count = self.active_count(fleet);
        let mut od_w = self.active_weight_of(fleet, Lifecycle::OnDemand);
        let mut killed = Vec::new();
        for (_, _, id, w, is_od) in victims {
            if count <= machines.max(1) {
                break;
            }
            if is_od && od_w.saturating_sub(w) < od_base {
                continue;
            }
            self.terminate(id, TerminationReason::FleetDownscale, now);
            count -= 1;
            if is_od {
                od_w -= w;
            }
            killed.push(id);
        }
        if !killed.is_empty() {
            let surviving = self.active_weight(fleet);
            self.modify_target(fleet, surviving);
        }
        killed
    }

    /// Raise an active fleet's requested capacity to `new_target` and
    /// immediately fill the deficit through the fleet's existing
    /// [`AllocationStrategy`] (weighted pools, on-demand base) — the
    /// scale-out half of the elastic loop, the inverse of
    /// [`scale_in`](Self::scale_in)'s cheapest-pool-last termination.
    /// Launches appear in the returned events exactly as an
    /// [`evaluate_fleets`](Self::evaluate_fleets) pass would report
    /// them; pools that are priced out or drained leave a
    /// [`FleetEvent::CapacityUnavailable`] residue and the regular
    /// per-minute evaluation keeps retrying toward the raised target.
    /// No-op (empty events) for cancelled fleets or non-raising targets.
    pub fn scale_out(
        &mut self,
        fleet: FleetId,
        new_target: u32,
        now: SimTime,
    ) -> Vec<FleetEvent> {
        let mut events = Vec::new();
        let Some(f) = self.fleets.get(&fleet) else {
            return events;
        };
        if f.state != FleetState::Active || new_target <= f.spec.target_capacity {
            return events;
        }
        self.modify_target(fleet, new_target);
        self.fulfill(fleet, now, &mut events);
        events
    }

    /// CancelSpotFleetRequests with TerminateInstances: end of run.
    pub fn cancel_fleet(&mut self, fleet: FleetId, now: SimTime) -> Vec<InstanceId> {
        let Some(f) = self.fleets.get_mut(&fleet) else {
            return Vec::new();
        };
        f.state = FleetState::Cancelled;
        let ids: Vec<InstanceId> = self
            .instances
            .values()
            .filter(|i| i.fleet == fleet && i.is_active())
            .map(|i| i.id)
            .collect();
        let mut ids = ids;
        ids.sort_unstable();
        for &id in &ids {
            self.terminate(id, TerminationReason::FleetCancelled, now);
        }
        ids
    }

    pub fn fleet_target(&self, fleet: FleetId) -> u32 {
        self.fleets
            .get(&fleet)
            .map(|f| f.spec.target_capacity)
            .unwrap_or(0)
    }

    pub fn fleet_is_active(&self, fleet: FleetId) -> bool {
        self.fleets
            .get(&fleet)
            .map(|f| f.state == FleetState::Active)
            .unwrap_or(false)
    }

    /// Number of non-terminated instances in a fleet.
    pub fn active_count(&self, fleet: FleetId) -> u32 {
        self.instances
            .values()
            .filter(|i| i.fleet == fleet && i.is_active())
            .count() as u32
    }

    /// Fulfilled weighted capacity: the sum of active instances' weights.
    /// Equals [`active_count`](Self::active_count) when every slot has
    /// weight 1.
    pub fn active_weight(&self, fleet: FleetId) -> u32 {
        self.instances
            .values()
            .filter(|i| i.fleet == fleet && i.is_active())
            .map(|i| i.weight)
            .sum()
    }

    /// Fulfilled weighted capacity bought with a given lifecycle.
    fn active_weight_of(&self, fleet: FleetId, lifecycle: Lifecycle) -> u32 {
        self.instances
            .values()
            .filter(|i| i.fleet == fleet && i.is_active() && i.lifecycle == lifecycle)
            .map(|i| i.weight)
            .sum()
    }

    /// All instance ids in a fleet in a given state, sorted.
    pub fn instances_in_state(&self, fleet: FleetId, state: InstanceState) -> Vec<InstanceId> {
        let mut v: Vec<InstanceId> = self
            .instances
            .values()
            .filter(|i| i.fleet == fleet && i.state == state)
            .map(|i| i.id)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn instance(&self, id: InstanceId) -> Option<&Instance> {
        self.instances.get(id)
    }

    pub fn instance_mut(&mut self, id: InstanceId) -> Option<&mut Instance> {
        self.instances.get_mut(id)
    }

    /// Fulfillment latency model.  Boot floor plus a "bid headroom" term:
    /// bidding barely above the price means waiting for capacity to turn
    /// over ("a couple of minutes to several hours").
    fn fulfillment_delay(rng: &mut SimRng, bid: f64, price: f64) -> SimTime {
        let boot = rng.range_u64(45 * SECOND, 120 * SECOND);
        let headroom = (bid / price - 1.0).max(0.0);
        if headroom > 0.5 {
            return boot; // comfortably above market: near-immediate
        }
        // Headroom 0..0.5 maps to an extra expected 0..~45 min wait.
        let tight = 1.0 - headroom / 0.5;
        let extra_mean = tight * tight * 45.0 * 60.0; // seconds
        let extra = rng.exp(extra_mean.max(1.0)).min(4.0 * 3_600.0);
        boot + (extra * 1_000.0) as SimTime
    }

    /// Launch one instance into a fleet and record the event.
    #[allow(clippy::too_many_arguments)]
    fn launch(
        &mut self,
        fleet: FleetId,
        tname: &'static str,
        weight: u32,
        bid: f64,
        lifecycle: Lifecycle,
        price: f64,
        domain: u32,
        now: SimTime,
        events: &mut Vec<FleetEvent>,
    ) {
        self.next_instance += 1;
        let id = self.next_instance;
        let ready_at = match lifecycle {
            Lifecycle::Spot => {
                now + Self::fulfillment_delay(&mut self.rng, bid * f64::from(weight), price)
            }
            // On-demand capacity is always there: boot time only.
            Lifecycle::OnDemand => now + self.rng.range_u64(45 * SECOND, 120 * SECOND),
        };
        self.instances.insert(
            id,
            Instance {
                id,
                itype: instance_type(tname).unwrap(),
                fleet,
                state: InstanceState::Pending,
                requested_at: now,
                running_at: None,
                terminated_at: None,
                termination_reason: None,
                crashed: false,
                bid,
                weight,
                lifecycle,
                name_tag: None,
                domain,
            },
        );
        events.push(FleetEvent::InstanceRequested {
            id,
            ready_at,
            itype: tname,
            price,
        });
    }

    /// One evaluation tick: interrupt out-bid spot instances, then fill
    /// any weighted deficit per the fleet's [`AllocationStrategy`].  The
    /// coordinator calls this on every market tick (once per simulated
    /// minute).
    pub fn evaluate_fleets(&mut self, now: SimTime) -> Vec<FleetEvent> {
        let mut events = Vec::new();

        // 1. Interruptions: pool price > effective bid.  On-demand
        //    instances are immune.
        let mut to_interrupt: Vec<(InstanceId, f64)> = Vec::new();
        for inst in self.instances.values() {
            if !inst.is_active() || inst.lifecycle != Lifecycle::Spot {
                continue;
            }
            let price = self.market.price_at_in(inst.domain, inst.itype.name, now);
            if price > inst.bid * f64::from(inst.weight) {
                to_interrupt.push((inst.id, price));
            }
        }
        to_interrupt.sort_unstable_by_key(|&(id, _)| id);
        for (id, price) in to_interrupt {
            self.terminate(id, TerminationReason::SpotInterruption, now);
            events.push(FleetEvent::InstanceInterrupted { id, price });
        }

        // 2. Fulfillment toward the weighted target.
        let fleet_ids: Vec<FleetId> = {
            let mut v: Vec<FleetId> = self
                .fleets
                .iter()
                .filter(|(_, f)| f.state == FleetState::Active)
                .map(|(&id, _)| id)
                .collect();
            v.sort_unstable();
            v
        };
        for fid in fleet_ids {
            self.fulfill(fid, now, &mut events);
        }
        events
    }

    /// Fill one active fleet's weighted deficit: the on-demand base
    /// floor first, then the spot deficit per the fleet's
    /// [`AllocationStrategy`].  Shared by the per-minute
    /// [`evaluate_fleets`](Self::evaluate_fleets) pass and the
    /// mid-run [`scale_out`](Self::scale_out) path, so elastic
    /// capacity launches into exactly the same pools a fresh fleet
    /// would.
    fn fulfill(&mut self, fid: FleetId, now: SimTime, events: &mut Vec<FleetEvent>) {
        let (target, bid, slots, allocation, od_base) = {
            let f = &self.fleets[&fid];
            (
                f.spec.target_capacity,
                f.spec.bid_hourly,
                f.spec.slots.clone(),
                f.spec.allocation,
                f.spec.on_demand_base,
            )
        };
        // Distinct pools in slot order (first occurrence's weight wins).
        let mut pools_spec: Vec<InstanceSlot> = Vec::new();
        for s in slots {
            if !pools_spec.iter().any(|p| p.name == s.name) {
                pools_spec.push(s);
            }
        }

        // 2a. On-demand base floor: fill from the cheapest per-unit
        //     on-demand pool; capacity is unconstrained.  On-demand
        //     always lands in the home domain — it is the survivable
        //     floor, and its flat price is domain-independent anyway.
        let od_floor = od_base.min(target);
        let od_active = self.active_weight_of(fid, Lifecycle::OnDemand);
        if od_active < od_floor {
            let mut od_deficit = od_floor - od_active;
            let pick = pools_spec
                .iter()
                .min_by(|a, b| {
                    let pa = per_unit(
                        instance_type(&a.name).unwrap().on_demand_hourly,
                        a.weight,
                    );
                    let pb = per_unit(
                        instance_type(&b.name).unwrap().on_demand_hourly,
                        b.weight,
                    );
                    pa.partial_cmp(&pb).unwrap().then(a.name.cmp(&b.name))
                })
                .cloned();
            if let Some(slot) = pick {
                let ty = instance_type(&slot.name).unwrap();
                while od_deficit > 0 {
                    self.launch(
                        fid,
                        ty.name,
                        slot.weight,
                        bid,
                        Lifecycle::OnDemand,
                        ty.on_demand_hourly,
                        0,
                        now,
                        events,
                    );
                    od_deficit = od_deficit.saturating_sub(slot.weight);
                }
            }
        }

        // 2b. Spot deficit per the allocation strategy, over the pools
        //     the placement policy exposes: the home domain only
        //     (no topology, or pack placement), or every domain's pools
        //     (spread / cheapest).
        let active = self.active_weight(fid);
        if active >= target {
            return;
        }
        let mut deficit = target - active;
        struct Pool {
            domain: u32,
            name: &'static str,
            weight: u32,
            price: f64,
            free: u32,
        }
        let domain_ids: Vec<u32> = if self.domains.is_empty()
            || self.placement == Placement::Pack
        {
            vec![0]
        } else {
            (0..self.domains.len() as u32).collect()
        };
        let mut pools: Vec<Pool> = Vec::new();
        for &d in &domain_ids {
            for s in &pools_spec {
                let Some(ty) = instance_type(&s.name) else {
                    continue;
                };
                let snap = self.market.snapshot_in(d, ty.name, now);
                if snap.price <= bid * f64::from(s.weight) && snap.free > 0 {
                    pools.push(Pool {
                        domain: d,
                        name: ty.name,
                        weight: s.weight,
                        price: snap.price,
                        free: snap.free,
                    });
                }
            }
        }
        let spread = !self.domains.is_empty() && self.placement == Placement::Spread;
        match allocation {
            AllocationStrategy::LowestPrice => pools.sort_by(|a, b| {
                per_unit(a.price, a.weight)
                    .partial_cmp(&per_unit(b.price, b.weight))
                    .unwrap()
                    .then(a.name.cmp(b.name))
                    .then(a.domain.cmp(&b.domain))
            }),
            AllocationStrategy::CapacityOptimized => pools.sort_by(|a, b| {
                b.free
                    .cmp(&a.free)
                    .then(
                        per_unit(a.price, a.weight)
                            .partial_cmp(&per_unit(b.price, b.weight))
                            .unwrap(),
                    )
                    .then(a.name.cmp(b.name))
                    .then(a.domain.cmp(&b.domain))
            }),
            // Diversified keeps slot order and spreads below.
            AllocationStrategy::Diversified => {}
        }
        if spread {
            // Spread placement: round-robin the *domains* (blast-radius
            // isolation), taking each domain's cheapest eligible pool —
            // pool-level strategy preferences are secondary to surviving
            // a whole-domain fault.
            let mut progressed = true;
            while deficit > 0 && progressed {
                progressed = false;
                for &d in &domain_ids {
                    if deficit == 0 {
                        break;
                    }
                    let Some(p) = pools
                        .iter_mut()
                        .filter(|p| p.domain == d && p.free > 0)
                        .min_by(|a, b| {
                            per_unit(a.price, a.weight)
                                .partial_cmp(&per_unit(b.price, b.weight))
                                .unwrap()
                                .then(a.name.cmp(b.name))
                        })
                    else {
                        continue;
                    };
                    p.free -= 1;
                    let (name, weight, price, domain) = (p.name, p.weight, p.price, p.domain);
                    self.launch(
                        fid,
                        name,
                        weight,
                        bid,
                        Lifecycle::Spot,
                        price,
                        domain,
                        now,
                        events,
                    );
                    deficit = deficit.saturating_sub(weight);
                    progressed = true;
                }
            }
        } else if allocation == AllocationStrategy::Diversified {
            let mut progressed = true;
            while deficit > 0 && progressed {
                progressed = false;
                for i in 0..pools.len() {
                    if deficit == 0 {
                        break;
                    }
                    if pools[i].free == 0 {
                        continue;
                    }
                    pools[i].free -= 1;
                    let (name, weight, price, domain) =
                        (pools[i].name, pools[i].weight, pools[i].price, pools[i].domain);
                    self.launch(
                        fid,
                        name,
                        weight,
                        bid,
                        Lifecycle::Spot,
                        price,
                        domain,
                        now,
                        events,
                    );
                    deficit = deficit.saturating_sub(weight);
                    progressed = true;
                }
            }
        } else {
            for p in &pools {
                if deficit == 0 {
                    break;
                }
                let need = (deficit + p.weight - 1) / p.weight;
                let take = need.min(p.free);
                for _ in 0..take {
                    self.launch(
                        fid,
                        p.name,
                        p.weight,
                        bid,
                        Lifecycle::Spot,
                        p.price,
                        p.domain,
                        now,
                        events,
                    );
                }
                deficit = deficit.saturating_sub(take * p.weight);
            }
        }
        if deficit > 0 {
            events.push(FleetEvent::CapacityUnavailable {
                fleet: fid,
                missing: deficit,
            });
        }
    }

    /// Boot complete: Pending → Running.  No-op if it died while booting.
    pub fn mark_running(&mut self, id: InstanceId, now: SimTime) -> bool {
        match self.instances.get_mut(id) {
            Some(i) if i.state == InstanceState::Pending => {
                i.state = InstanceState::Running;
                i.running_at = Some(now);
                true
            }
            _ => false,
        }
    }

    /// TerminateInstances: bill and mark.  Idempotent.
    pub fn terminate(&mut self, id: InstanceId, reason: TerminationReason, now: SimTime) {
        let Some(inst) = self.instances.get_mut(id) else {
            return;
        };
        if inst.state == InstanceState::Terminated {
            return;
        }
        inst.state = InstanceState::Terminated;
        inst.terminated_at = Some(now);
        inst.termination_reason = Some(reason);
        let itype = inst.itype.name;
        let od_hourly = inst.itype.on_demand_hourly;
        let lifecycle = inst.lifecycle;
        let domain = inst.domain;
        // AWS bills Linux spot per-second with a 60-second minimum: even
        // a boot-poll-shutdown instance costs a billing minute (this is
        // what makes unmonitored churn expensive — experiment T3/T7).
        if let Some(start) = inst.running_at {
            let end = now.max(start + crate::sim::MINUTE);
            let cost =
                billed_cost(&mut self.market, itype, od_hourly, lifecycle, domain, start, end);
            self.cost_log.push(CostRecord {
                instance: id,
                itype,
                lifecycle,
                span: (start, end),
                cost_usd: cost,
                reason,
                domain,
            });
        }
    }

    /// Billed instance lifetimes so far.
    pub fn cost_log(&self) -> &[CostRecord] {
        &self.cost_log
    }

    /// Bill any still-running instances up to `now` (end-of-run report for
    /// scenarios that never tear down).
    pub fn accrued_cost_of_active(&mut self, now: SimTime) -> f64 {
        let spans: Vec<(&'static str, Lifecycle, f64, u32, SimTime, SimTime)> = self
            .all_instances()
            .into_iter()
            .filter(|i| i.is_active())
            .filter_map(|i| {
                i.billable_span(now).map(|(s, e)| {
                    (i.itype.name, i.lifecycle, i.itype.on_demand_hourly, i.domain, s, e)
                })
            })
            .collect();
        spans
            .into_iter()
            .map(|(t, lc, od, d, s, e)| billed_cost(&mut self.market, t, od, lc, d, s, e))
            .sum()
    }

    /// Per-pool slice of everything this account's fleets did: launches,
    /// interruptions, billed machine-hours and dollars (terminated
    /// lifetimes plus accrual of still-running instances up to `now`).
    /// Rows are sorted by pool label, so the output is deterministic.
    pub fn pool_breakdown(&mut self, now: SimTime) -> Vec<PoolBreakdown> {
        let mut map: BTreeMap<String, PoolBreakdown> = BTreeMap::new();
        // One pass over the instance table (sorted by id so f64
        // accumulation order is replay-stable): launch/interruption
        // counters, plus the billable spans of still-active instances.
        let mut active: Vec<(String, &'static str, Lifecycle, f64, u32, SimTime, SimTime)> =
            Vec::new();
        for inst in self.all_instances() {
            let key = pool_label(inst.itype.name, inst.lifecycle, inst.domain, &self.domains);
            if inst.is_active() {
                if let Some((s, e)) = inst.billable_span(now) {
                    active.push((
                        key.clone(),
                        inst.itype.name,
                        inst.lifecycle,
                        inst.itype.on_demand_hourly,
                        inst.domain,
                        s,
                        e,
                    ));
                }
            }
            let e = map
                .entry(key.clone())
                .or_insert_with(|| PoolBreakdown::empty(key));
            e.launched += 1;
            if inst.termination_reason == Some(TerminationReason::SpotInterruption) {
                e.interrupted += 1;
            }
        }
        // Billed lifetimes (insertion order: termination order).
        for rec in &self.cost_log {
            let key = pool_label(rec.itype, rec.lifecycle, rec.domain, &self.domains);
            let e = map
                .entry(key.clone())
                .or_insert_with(|| PoolBreakdown::empty(key));
            e.machine_hours += (rec.span.1 - rec.span.0) as f64 / crate::sim::HOUR as f64;
            e.cost_usd += rec.cost_usd;
        }
        // Accrue the still-running spans collected above.
        for (key, tname, lc, od, d, s, e) in active {
            let cost = billed_cost(&mut self.market, tname, od, lc, d, s, e);
            let entry = map
                .entry(key.clone())
                .or_insert_with(|| PoolBreakdown::empty(key));
            entry.machine_hours += (e - s) as f64 / crate::sim::HOUR as f64;
            entry.cost_usd += cost;
        }
        map.into_values().collect()
    }

    /// Per-failure-domain slice of the fleet activity: launches,
    /// correlated + market interruptions, and billed dollars (terminated
    /// lifetimes plus accrual of still-running instances up to `now`).
    /// One row per installed domain, declaration order; empty without a
    /// topology.
    pub fn domain_breakdown(&mut self, now: SimTime) -> Vec<DomainUsage> {
        let n = self.domains.len();
        let mut out = vec![DomainUsage::default(); n];
        if n == 0 {
            return out;
        }
        let mut active: Vec<(&'static str, Lifecycle, f64, u32, SimTime, SimTime)> = Vec::new();
        for inst in self.all_instances() {
            let Some(slot) = out.get_mut(inst.domain as usize) else {
                continue;
            };
            slot.launched += 1;
            if matches!(
                inst.termination_reason,
                Some(TerminationReason::SpotInterruption) | Some(TerminationReason::AzOutage)
            ) {
                slot.interrupted += 1;
            }
            if inst.is_active() {
                if let Some((s, e)) = inst.billable_span(now) {
                    active.push((
                        inst.itype.name,
                        inst.lifecycle,
                        inst.itype.on_demand_hourly,
                        inst.domain,
                        s,
                        e,
                    ));
                }
            }
        }
        for rec in &self.cost_log {
            if let Some(slot) = out.get_mut(rec.domain as usize) {
                slot.cost_usd += rec.cost_usd;
            }
        }
        for (tname, lc, od, d, s, e) in active {
            let cost = billed_cost(&mut self.market, tname, od, lc, d, s, e);
            out[d as usize].cost_usd += cost;
        }
        out
    }

    /// All instances (sorted by id) — used by reports and tests.
    pub fn all_instances(&self) -> Vec<&Instance> {
        // IdStore iterates in ascending-id order on both backends.
        self.instances.values().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::ec2::market::Volatility;
    use crate::sim::{HOUR, MINUTE};

    fn ec2(vol: Volatility, seed: u64) -> Ec2 {
        Ec2::new(SpotMarket::new(seed, vol), SimRng::new(seed ^ 0xEC2))
    }

    fn spec(n: u32, bid: f64) -> SpotFleetSpec {
        SpotFleetSpec::homogeneous(n, bid, "m5.large")
    }

    fn count_by_type(e: &Ec2, tname: &str) -> usize {
        e.all_instances()
            .iter()
            .filter(|i| i.itype.name == tname && i.is_active())
            .count()
    }

    #[test]
    fn fleet_fulfills_to_target() {
        let mut e = ec2(Volatility::Low, 1);
        let fid = e.request_spot_fleet(spec(8, 0.09));
        let evs = e.evaluate_fleets(0);
        let launched = evs
            .iter()
            .filter(|ev| matches!(ev, FleetEvent::InstanceRequested { .. }))
            .count();
        assert_eq!(launched, 8);
        assert_eq!(e.active_count(fid), 8);
        assert_eq!(e.active_weight(fid), 8);
        // Second tick: no extra launches.
        assert!(e.evaluate_fleets(MINUTE).is_empty());
    }

    #[test]
    fn low_bid_gets_no_machines() {
        let mut e = ec2(Volatility::Low, 2);
        let fid = e.request_spot_fleet(spec(4, 0.001)); // far below base
        let evs = e.evaluate_fleets(0);
        assert!(matches!(
            evs.as_slice(),
            [FleetEvent::CapacityUnavailable { missing: 4, .. }]
        ));
        assert_eq!(e.active_count(fid), 0);
    }

    #[test]
    fn high_bid_fulfills_faster_than_tight_bid() {
        // Statistical: mean ready_at over many instances.
        let mean_delay = |bid: f64, seed: u64| -> f64 {
            let mut e = ec2(Volatility::Low, seed);
            e.request_spot_fleet(SpotFleetSpec {
                target_capacity: 50,
                bid_hourly: bid,
                slots: vec![InstanceSlot::new("m5.large")],
                ..Default::default()
            });
            let evs = e.evaluate_fleets(0);
            let delays: Vec<f64> = evs
                .iter()
                .filter_map(|ev| match ev {
                    FleetEvent::InstanceRequested { ready_at, .. } => {
                        Some(*ready_at as f64)
                    }
                    _ => None,
                })
                .collect();
            delays.iter().sum::<f64>() / delays.len() as f64
        };
        let base = 0.096 * 0.31;
        let tight = mean_delay(base * 1.02, 3);
        let comfy = mean_delay(base * 2.0, 3);
        assert!(
            tight > comfy * 2.0,
            "tight bid should wait longer: tight={tight} comfy={comfy}"
        );
    }

    #[test]
    fn interruption_when_price_exceeds_bid() {
        // High volatility + bid at base: must eventually interrupt.
        let mut e = ec2(Volatility::High, 5);
        let base = 0.096 * 0.31;
        let fid = e.request_spot_fleet(spec(4, base * 1.05));
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        let mut interrupted = 0;
        for k in 1..(48 * 60) {
            let evs = e.evaluate_fleets(k * MINUTE);
            interrupted += evs
                .iter()
                .filter(|ev| matches!(ev, FleetEvent::InstanceInterrupted { .. }))
                .count();
            for ev in &evs {
                if let FleetEvent::InstanceRequested { id, .. } = ev {
                    e.mark_running(*id, k * MINUTE + 1);
                }
            }
        }
        assert!(interrupted > 0, "48h of high volatility, no interruptions?");
        // Fleet kept replacing: still near target at the end.
        assert!(e.active_count(fid) >= 3);
    }

    #[test]
    fn terminate_bills_once() {
        let mut e = ec2(Volatility::Low, 7);
        let _fid = e.request_spot_fleet(spec(1, 0.09));
        let evs = e.evaluate_fleets(0);
        let id = match &evs[0] {
            FleetEvent::InstanceRequested { id, .. } => *id,
            _ => panic!(),
        };
        e.mark_running(id, MINUTE);
        e.terminate(id, TerminationReason::SelfShutdown, HOUR);
        e.terminate(id, TerminationReason::SelfShutdown, 2 * HOUR); // no double bill
        assert_eq!(e.cost_log().len(), 1);
        let rec = &e.cost_log()[0];
        assert_eq!(rec.reason, TerminationReason::SelfShutdown);
        assert_eq!(rec.lifecycle, Lifecycle::Spot);
        // ~59 minutes of m5.large spot ≈ base price
        assert!(rec.cost_usd > 0.0 && rec.cost_usd < 0.096);
    }

    #[test]
    fn modify_target_does_not_kill_running() {
        let mut e = ec2(Volatility::Low, 9);
        let fid = e.request_spot_fleet(spec(6, 0.09));
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        e.modify_target(fid, 1); // cheapest mode
        e.evaluate_fleets(2 * MINUTE);
        assert_eq!(e.active_count(fid), 6, "cheapest mode must not terminate");
        // But a death is not replaced.
        let victim = e.instances_in_state(fid, InstanceState::Running)[0];
        e.terminate(victim, TerminationReason::Crash, 3 * MINUTE);
        e.evaluate_fleets(4 * MINUTE);
        assert_eq!(e.active_count(fid), 5);
    }

    #[test]
    fn cancel_fleet_terminates_everything() {
        let mut e = ec2(Volatility::Low, 11);
        let fid = e.request_spot_fleet(spec(5, 0.09));
        e.evaluate_fleets(0);
        let killed = e.cancel_fleet(fid, 10 * MINUTE);
        assert_eq!(killed.len(), 5);
        assert_eq!(e.active_count(fid), 0);
        // Cancelled fleet never relaunches.
        assert!(e.evaluate_fleets(11 * MINUTE).is_empty());
    }

    #[test]
    fn replacement_after_alarm_termination() {
        let mut e = ec2(Volatility::Low, 13);
        let fid = e.request_spot_fleet(spec(3, 0.09));
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        let victim = e.instances_in_state(fid, InstanceState::Running)[0];
        e.terminate(victim, TerminationReason::AlarmAction, 5 * MINUTE);
        assert_eq!(e.active_count(fid), 2);
        let evs = e.evaluate_fleets(6 * MINUTE);
        assert_eq!(
            evs.iter()
                .filter(|ev| matches!(ev, FleetEvent::InstanceRequested { .. }))
                .count(),
            1
        );
        assert_eq!(e.active_count(fid), 3);
    }

    #[test]
    fn allocation_prefers_cheapest_pool() {
        let mut e = ec2(Volatility::Low, 15);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 2,
            bid_hourly: 0.50,
            slots: vec![
                InstanceSlot::new("m5.2xlarge"),
                InstanceSlot::new("m5.large"),
            ],
            ..Default::default()
        });
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            let t = e.instance(id).unwrap().itype.name;
            assert_eq!(t, "m5.large", "should pick the cheaper pool");
        }
    }

    #[test]
    fn diversified_spreads_across_pools() {
        let mut e = ec2(Volatility::Low, 19);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 9,
            bid_hourly: 0.50,
            slots: vec![
                InstanceSlot::new("m5.large"),
                InstanceSlot::new("c5.xlarge"),
                InstanceSlot::new("r5.xlarge"),
            ],
            allocation: AllocationStrategy::Diversified,
            ..Default::default()
        });
        e.evaluate_fleets(0);
        assert_eq!(e.active_weight(fid), 9);
        assert_eq!(count_by_type(&e, "m5.large"), 3);
        assert_eq!(count_by_type(&e, "c5.xlarge"), 3);
        assert_eq!(count_by_type(&e, "r5.xlarge"), 3);
    }

    #[test]
    fn capacity_optimized_prefers_deep_pools() {
        // m5.large's pool (400) dwarfs m5.12xlarge's (24): capacity-
        // optimized allocation must go where the machines are.
        let mut e = ec2(Volatility::Low, 21);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 4,
            bid_hourly: 2.50, // both pools eligible
            slots: vec![
                InstanceSlot::new("m5.12xlarge"),
                InstanceSlot::new("m5.large"),
            ],
            allocation: AllocationStrategy::CapacityOptimized,
            ..Default::default()
        });
        e.evaluate_fleets(0);
        assert_eq!(e.active_weight(fid), 4);
        assert_eq!(count_by_type(&e, "m5.large"), 4);
        assert_eq!(count_by_type(&e, "m5.12xlarge"), 0);
    }

    #[test]
    fn weighted_slots_fulfill_in_units_not_instances() {
        let mut e = ec2(Volatility::Low, 23);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 5,
            bid_hourly: 0.10, // per unit; m5.xlarge effective bid 0.20
            slots: vec![InstanceSlot {
                name: "m5.xlarge".into(),
                weight: 2,
            }],
            ..Default::default()
        });
        e.evaluate_fleets(0);
        // ceil(5 units / weight 2) = 3 instances = 6 units.
        assert_eq!(e.active_count(fid), 3);
        assert_eq!(e.active_weight(fid), 6);
        // Overshoot is bounded by one slot's weight.
        assert!(e.active_weight(fid) < 5 + 2);
        // And stays put on the next tick.
        assert!(e.evaluate_fleets(MINUTE).is_empty());
    }

    #[test]
    fn on_demand_base_survives_any_market() {
        let mut e = ec2(Volatility::High, 25);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 4,
            bid_hourly: 0.001, // spot hopeless: only the od base launches
            slots: vec![InstanceSlot::new("m5.large")],
            on_demand_base: 2,
            ..Default::default()
        });
        let evs = e.evaluate_fleets(0);
        let launched: Vec<InstanceId> = evs
            .iter()
            .filter_map(|ev| match ev {
                FleetEvent::InstanceRequested { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(launched.len(), 2);
        assert!(matches!(
            evs.last(),
            Some(FleetEvent::CapacityUnavailable { missing: 2, .. })
        ));
        for &id in &launched {
            assert_eq!(e.instance(id).unwrap().lifecycle, Lifecycle::OnDemand);
            e.mark_running(id, MINUTE);
        }
        // A week of high volatility: the on-demand floor is never
        // interrupted.
        for k in 1..(7 * 24 * 60) {
            let evs = e.evaluate_fleets(k * MINUTE);
            assert!(
                !evs.iter()
                    .any(|ev| matches!(ev, FleetEvent::InstanceInterrupted { .. })),
                "on-demand instance interrupted at tick {k}"
            );
        }
        assert_eq!(e.active_count(fid), 2);
    }

    #[test]
    fn on_demand_bills_flat_catalog_rate() {
        let mut e = ec2(Volatility::High, 27);
        let _fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 1,
            bid_hourly: 0.001,
            slots: vec![InstanceSlot::new("m5.large")],
            on_demand_base: 1,
            ..Default::default()
        });
        let evs = e.evaluate_fleets(0);
        let id = evs
            .iter()
            .find_map(|ev| match ev {
                FleetEvent::InstanceRequested { id, .. } => Some(*id),
                _ => None,
            })
            .unwrap();
        e.mark_running(id, 0);
        e.terminate(id, TerminationReason::SelfShutdown, 2 * HOUR);
        let rec = &e.cost_log()[0];
        assert_eq!(rec.lifecycle, Lifecycle::OnDemand);
        // Exactly 2h × $0.096/h, independent of the (spiky) spot path.
        assert!((rec.cost_usd - 0.192).abs() < 1e-9, "cost={}", rec.cost_usd);
    }

    #[test]
    fn scale_in_downscales_cheapest_pool_last() {
        let mut e = ec2(Volatility::Low, 29);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 4,
            bid_hourly: 0.50,
            slots: vec![
                InstanceSlot::new("m5.large"),  // spot ~0.030/h
                InstanceSlot::new("c5.xlarge"), // spot ~0.054/h
            ],
            allocation: AllocationStrategy::Diversified,
            ..Default::default()
        });
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        assert_eq!(count_by_type(&e, "m5.large"), 2);
        assert_eq!(count_by_type(&e, "c5.xlarge"), 2);
        let killed = e.scale_in(fid, 2, 5 * MINUTE);
        assert_eq!(killed.len(), 2);
        assert_eq!(e.active_weight(fid), 2);
        assert_eq!(e.fleet_target(fid), 2);
        // The expensive pool died; the cheap one survived.
        assert_eq!(count_by_type(&e, "c5.xlarge"), 0);
        assert_eq!(count_by_type(&e, "m5.large"), 2);
        for id in killed {
            assert_eq!(
                e.instance(id).unwrap().termination_reason,
                Some(TerminationReason::FleetDownscale)
            );
        }
        // No relaunch: target was lowered too.
        assert!(e.evaluate_fleets(6 * MINUTE).is_empty());
    }

    #[test]
    fn scale_in_preserves_on_demand_floor() {
        // The od base is the most expensive slice per hour, but killing
        // it would just make evaluate_fleets rebuy it (churn + a wasted
        // billing minute), so scale_in must keep it.
        let mut e = ec2(Volatility::Low, 33);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 4,
            bid_hourly: 0.50,
            slots: vec![InstanceSlot::new("m5.large")],
            on_demand_base: 2,
            ..Default::default()
        });
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        assert_eq!(e.active_weight(fid), 4);
        let killed = e.scale_in(fid, 2, 5 * MINUTE);
        // Both spot instances died (od bills $0.096/h > spot ~$0.03/h,
        // so od would otherwise rank first); the od floor survived.
        assert_eq!(killed.len(), 2);
        let survivors: Vec<Lifecycle> = e
            .all_instances()
            .iter()
            .filter(|i| i.is_active())
            .map(|i| i.lifecycle)
            .collect();
        assert_eq!(survivors, vec![Lifecycle::OnDemand, Lifecycle::OnDemand]);
        // Stable: the next tick neither rebuys nor interrupts.
        assert!(e.evaluate_fleets(6 * MINUTE).is_empty());
        // Scaling BELOW the od base clamps the floor to the new target:
        // one od instance is released (it would not be rebought, since
        // evaluate's floor is od_base.min(target) = 1).
        let killed = e.scale_in(fid, 1, 7 * MINUTE);
        assert_eq!(killed.len(), 1);
        assert_eq!(e.active_weight(fid), 1);
        assert!(e.evaluate_fleets(8 * MINUTE).is_empty());
    }

    #[test]
    fn scale_in_to_machines_budgets_instances_not_units() {
        // Three weight-3 machines = 9 units.  A machine budget of 2 must
        // keep 2 machines (6 units), not 2 units.
        let mut e = ec2(Volatility::Low, 35);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 9,
            bid_hourly: 0.10, // per unit: m5.xlarge:3 effective bid 0.30
            slots: vec![InstanceSlot {
                name: "m5.xlarge".into(),
                weight: 3,
            }],
            ..Default::default()
        });
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        assert_eq!(e.active_count(fid), 3);
        let killed = e.scale_in_to_machines(fid, 2, 5 * MINUTE);
        assert_eq!(killed.len(), 1);
        assert_eq!(e.active_count(fid), 2);
        assert_eq!(e.active_weight(fid), 6);
        // Requested capacity follows the survivors: no relaunch.
        assert_eq!(e.fleet_target(fid), 6);
        assert!(e.evaluate_fleets(6 * MINUTE).is_empty());
    }

    #[test]
    fn scale_out_launches_mid_run_via_allocation_strategy() {
        let mut e = ec2(Volatility::Low, 37);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 2,
            bid_hourly: 0.50,
            slots: vec![
                InstanceSlot::new("m5.large"),
                InstanceSlot::new("c5.xlarge"),
            ],
            allocation: AllocationStrategy::Diversified,
            ..Default::default()
        });
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        assert_eq!(e.active_weight(fid), 2);
        // Mid-run scale-out: launches immediately, diversified across
        // both pools, without waiting for the next evaluation tick.
        let evs = e.scale_out(fid, 6, 5 * MINUTE);
        assert_eq!(
            evs.iter()
                .filter(|ev| matches!(ev, FleetEvent::InstanceRequested { .. }))
                .count(),
            4
        );
        assert_eq!(e.fleet_target(fid), 6);
        assert_eq!(e.active_weight(fid), 6);
        assert_eq!(count_by_type(&e, "m5.large"), 3);
        assert_eq!(count_by_type(&e, "c5.xlarge"), 3);
        // Settled: the next tick neither launches nor interrupts.
        assert!(e.evaluate_fleets(6 * MINUTE).is_empty());
    }

    #[test]
    fn scale_out_is_a_noop_when_not_raising() {
        let mut e = ec2(Volatility::Low, 39);
        let fid = e.request_spot_fleet(spec(4, 0.09));
        e.evaluate_fleets(0);
        assert!(e.scale_out(fid, 4, MINUTE).is_empty(), "same target");
        assert!(e.scale_out(fid, 2, MINUTE).is_empty(), "lower target");
        assert_eq!(e.fleet_target(fid), 4, "target untouched");
        assert!(e.scale_out(999, 8, MINUTE).is_empty(), "unknown fleet");
        e.cancel_fleet(fid, 2 * MINUTE);
        assert!(e.scale_out(fid, 8, 3 * MINUTE).is_empty(), "cancelled fleet");
    }

    #[test]
    fn scale_out_reports_unavailable_capacity_and_retries() {
        // A hopeless bid: the raised target is remembered and the next
        // evaluation keeps trying (the fleet replaces toward target).
        let mut e = ec2(Volatility::Low, 41);
        let fid = e.request_spot_fleet(spec(1, 0.09));
        e.evaluate_fleets(0);
        // Drop the bid below the market, then scale out.
        if let Some(f) = e.fleets.get_mut(&fid) {
            f.spec.bid_hourly = 0.001;
        }
        let evs = e.scale_out(fid, 3, MINUTE);
        assert!(matches!(
            evs.as_slice(),
            [FleetEvent::CapacityUnavailable { missing: 2, .. }]
        ));
        assert_eq!(e.fleet_target(fid), 3);
        // Market recovers (bid restored): the regular tick fulfills.
        if let Some(f) = e.fleets.get_mut(&fid) {
            f.spec.bid_hourly = 0.09;
        }
        let evs = e.evaluate_fleets(2 * MINUTE);
        assert_eq!(
            evs.iter()
                .filter(|ev| matches!(ev, FleetEvent::InstanceRequested { .. }))
                .count(),
            2
        );
    }

    #[test]
    fn pool_breakdown_slices_by_pool_and_lifecycle() {
        let mut e = ec2(Volatility::Low, 31);
        let fid = e.request_spot_fleet(SpotFleetSpec {
            target_capacity: 4,
            bid_hourly: 0.50,
            slots: vec![
                InstanceSlot::new("m5.large"),
                InstanceSlot::new("c5.xlarge"),
            ],
            allocation: AllocationStrategy::Diversified,
            on_demand_base: 1,
            ..Default::default()
        });
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        e.cancel_fleet(fid, 2 * HOUR);
        let pools = e.pool_breakdown(2 * HOUR);
        let labels: Vec<&str> = pools.iter().map(|p| p.pool.as_str()).collect();
        assert_eq!(labels, vec!["c5.xlarge", "m5.large", "m5.large/on-demand"]);
        let total_launched: u64 = pools.iter().map(|p| p.launched).sum();
        assert_eq!(total_launched, 4);
        for p in &pools {
            assert!(p.cost_usd > 0.0, "{p:?}");
            assert!(p.machine_hours > 0.0, "{p:?}");
        }
        // Breakdown total matches the cost log total.
        let log_total: f64 = e.cost_log().iter().map(|r| r.cost_usd).sum();
        let pool_total: f64 = pools.iter().map(|p| p.cost_usd).sum();
        assert!((log_total - pool_total).abs() < 1e-12);
    }

    #[test]
    fn allocation_strategy_names_roundtrip() {
        for a in AllocationStrategy::ALL {
            assert_eq!(AllocationStrategy::parse(a.name()), Some(a));
        }
        assert_eq!(AllocationStrategy::parse("bogus"), None);
    }

    #[test]
    fn instance_slot_parse_and_render() {
        let s = InstanceSlot::parse("m5.xlarge").unwrap();
        assert_eq!((s.name.as_str(), s.weight), ("m5.xlarge", 1));
        assert_eq!(s.render(), "m5.xlarge");
        let s = InstanceSlot::parse(" r5.xlarge : 3 ").unwrap();
        assert_eq!((s.name.as_str(), s.weight), ("r5.xlarge", 3));
        assert_eq!(s.render(), "r5.xlarge:3");
        assert!(InstanceSlot::parse("m5.large:0").is_err());
        assert!(InstanceSlot::parse("m5.large:x").is_err());
        assert!(InstanceSlot::parse(":2").is_err());
    }

    #[test]
    fn unknown_type_panics() {
        let mut e = ec2(Volatility::Low, 17);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e.request_spot_fleet(SpotFleetSpec {
                target_capacity: 1,
                bid_hourly: 1.0,
                slots: vec![InstanceSlot::new("quantum.9000xl")],
                ..Default::default()
            })
        }));
        assert!(r.is_err());
    }

    fn ec2_with_domains(seed: u64, placement: Placement) -> Ec2 {
        let mut e = ec2(Volatility::Low, seed);
        e.install_topology(
            vec!["us-east-1a".to_string(), "us-west-2a".to_string()],
            placement,
        );
        e
    }

    fn domain_counts(e: &Ec2) -> Vec<usize> {
        let mut v = vec![0usize; e.domains().len()];
        for i in e.all_instances() {
            if i.is_active() {
                v[i.domain as usize] += 1;
            }
        }
        v
    }

    #[test]
    fn pack_placement_fills_the_home_domain_only() {
        let mut e = ec2_with_domains(61, Placement::Pack);
        let fid = e.request_spot_fleet(spec(4, 0.09));
        e.evaluate_fleets(0);
        assert_eq!(e.active_weight(fid), 4);
        assert_eq!(domain_counts(&e), vec![4, 0]);
        assert_eq!(e.active_in_domain(0).len(), 4);
        assert!(e.active_in_domain(1).is_empty());
    }

    #[test]
    fn spread_placement_round_robins_domains() {
        let mut e = ec2_with_domains(63, Placement::Spread);
        let fid = e.request_spot_fleet(spec(4, 0.09));
        e.evaluate_fleets(0);
        assert_eq!(e.active_weight(fid), 4);
        assert_eq!(domain_counts(&e), vec![2, 2]);
    }

    #[test]
    fn spread_survives_a_home_domain_outage() {
        use crate::aws::ec2::market::{MarketFault, MarketFaultKind};
        let mut e = ec2_with_domains(65, Placement::Spread);
        e.market.install_fault(MarketFault {
            domain: 0,
            kind: MarketFaultKind::Outage,
            start: 0,
            end: 10 * HOUR,
            magnitude: 1.0,
        });
        let fid = e.request_spot_fleet(spec(4, 0.09));
        e.evaluate_fleets(0);
        // The home domain is dark: everything lands in the survivor.
        assert_eq!(e.active_weight(fid), 4);
        assert_eq!(domain_counts(&e), vec![0, 4]);
        // Pack placement under the same outage gets nothing.
        let mut p = ec2_with_domains(65, Placement::Pack);
        p.market.install_fault(MarketFault {
            domain: 0,
            kind: MarketFaultKind::Outage,
            start: 0,
            end: 10 * HOUR,
            magnitude: 1.0,
        });
        let pf = p.request_spot_fleet(spec(4, 0.09));
        let evs = p.evaluate_fleets(0);
        assert_eq!(p.active_weight(pf), 0);
        assert!(matches!(
            evs.as_slice(),
            [FleetEvent::CapacityUnavailable { missing: 4, .. }]
        ));
    }

    #[test]
    fn cheapest_placement_takes_the_lowest_priced_domain() {
        use crate::aws::ec2::market::{MarketFault, MarketFaultKind};
        let mut e = ec2_with_domains(67, Placement::Cheapest);
        // Make the home domain expensive: cheapest must flee to domain 1.
        e.market.install_fault(MarketFault {
            domain: 0,
            kind: MarketFaultKind::PriceStorm,
            start: 0,
            end: 10 * HOUR,
            magnitude: 10.0,
        });
        let fid = e.request_spot_fleet(spec(4, 0.50));
        e.evaluate_fleets(0);
        assert_eq!(e.active_weight(fid), 4);
        assert_eq!(domain_counts(&e), vec![0, 4]);
    }

    #[test]
    fn domain_labels_and_breakdown_slice_by_domain() {
        let mut e = ec2_with_domains(69, Placement::Spread);
        let fid = e.request_spot_fleet(spec(4, 0.09));
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        e.cancel_fleet(fid, 2 * HOUR);
        let pools = e.pool_breakdown(2 * HOUR);
        let labels: Vec<&str> = pools.iter().map(|p| p.pool.as_str()).collect();
        assert_eq!(labels, vec!["m5.large@us-east-1a", "m5.large@us-west-2a"]);
        let d = e.domain_breakdown(2 * HOUR);
        assert_eq!(d.len(), 2);
        assert_eq!((d[0].launched, d[1].launched), (2, 2));
        assert!(d[0].cost_usd > 0.0 && d[1].cost_usd > 0.0);
        // Domain slices cover the same dollars as the pools.
        let pool_total: f64 = pools.iter().map(|p| p.cost_usd).sum();
        let dom_total: f64 = d.iter().map(|s| s.cost_usd).sum();
        assert!((pool_total - dom_total).abs() < 1e-12);
    }

    #[test]
    fn az_outage_kills_count_as_interruptions_in_domain_slices() {
        let mut e = ec2_with_domains(71, Placement::Spread);
        let fid = e.request_spot_fleet(spec(4, 0.09));
        e.evaluate_fleets(0);
        for id in e.instances_in_state(fid, InstanceState::Pending) {
            e.mark_running(id, MINUTE);
        }
        for id in e.active_in_domain(0) {
            e.terminate(id, TerminationReason::AzOutage, 5 * MINUTE);
        }
        let d = e.domain_breakdown(10 * MINUTE);
        assert_eq!(d[0].interrupted, 2);
        assert_eq!(d[1].interrupted, 0);
    }
}
