//! Instance-type catalog: shapes and on-demand prices.
//!
//! A representative slice of the m5/c5/r5 families (the paper's docs use
//! the ECS-optimized AMI on general-purpose instances; Distributed-Fiji's
//! stitching example wants one big machine, hence the 12xlarge).  Prices
//! are 2022-era us-east-1 on-demand USD/hour — absolute values only anchor
//! the cost *ratios* the experiments report.

/// Static description of an EC2 instance type.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceType {
    pub name: &'static str,
    pub vcpus: u32,
    pub memory_mb: u64,
    /// On-demand price, USD per hour.
    pub on_demand_hourly: f64,
    /// Long-run average spot discount (spot base ≈ this × on-demand).
    pub spot_base_fraction: f64,
    /// Nominal pool capacity (instances available to this account/region).
    pub pool_capacity: u32,
    /// Sustainable network bandwidth, Gbit/s (the baseline, not the "up
    /// to 10 Gbit" burst figure marketing quotes): what the data plane's
    /// transfer scheduler lets concurrent S3 flows share on this machine.
    pub nic_gbps: f64,
}

/// The catalog.  Ordered roughly by size within family.
pub const INSTANCE_TYPES: &[InstanceType] = &[
    InstanceType { name: "m5.large",    vcpus: 2,  memory_mb: 8_192,   on_demand_hourly: 0.096, spot_base_fraction: 0.31, pool_capacity: 400, nic_gbps: 0.75 },
    InstanceType { name: "m5.xlarge",   vcpus: 4,  memory_mb: 16_384,  on_demand_hourly: 0.192, spot_base_fraction: 0.30, pool_capacity: 300, nic_gbps: 1.25 },
    InstanceType { name: "m5.2xlarge",  vcpus: 8,  memory_mb: 32_768,  on_demand_hourly: 0.384, spot_base_fraction: 0.31, pool_capacity: 200, nic_gbps: 2.5 },
    InstanceType { name: "m5.4xlarge",  vcpus: 16, memory_mb: 65_536,  on_demand_hourly: 0.768, spot_base_fraction: 0.33, pool_capacity: 120, nic_gbps: 5.0 },
    InstanceType { name: "m5.12xlarge", vcpus: 48, memory_mb: 196_608, on_demand_hourly: 2.304, spot_base_fraction: 0.35, pool_capacity: 24,  nic_gbps: 12.0 },
    InstanceType { name: "c5.xlarge",   vcpus: 4,  memory_mb: 8_192,   on_demand_hourly: 0.170, spot_base_fraction: 0.32, pool_capacity: 250, nic_gbps: 1.25 },
    InstanceType { name: "c5.2xlarge",  vcpus: 8,  memory_mb: 16_384,  on_demand_hourly: 0.340, spot_base_fraction: 0.33, pool_capacity: 160, nic_gbps: 2.5 },
    InstanceType { name: "r5.xlarge",   vcpus: 4,  memory_mb: 32_768,  on_demand_hourly: 0.252, spot_base_fraction: 0.32, pool_capacity: 150, nic_gbps: 1.25 },
];

impl InstanceType {
    /// Long-run average spot price (USD/h): the level the per-pool price
    /// walk mean-reverts to.
    pub fn spot_base(&self) -> f64 {
        self.on_demand_hourly * self.spot_base_fraction
    }
}

/// Look up a type by name.
///
/// ```
/// use ds_rs::aws::ec2::instance_type;
/// let t = instance_type("m5.xlarge").unwrap();
/// assert_eq!((t.vcpus, t.memory_mb), (4, 16_384));
/// assert!(instance_type("warp9.mega").is_none());
/// ```
pub fn instance_type(name: &str) -> Option<&'static InstanceType> {
    INSTANCE_TYPES.iter().find(|t| t.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_known_types() {
        let t = instance_type("m5.xlarge").unwrap();
        assert_eq!(t.vcpus, 4);
        assert_eq!(t.memory_mb, 16_384);
        assert!(instance_type("x1e.nope").is_none());
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = INSTANCE_TYPES.iter().map(|t| t.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), INSTANCE_TYPES.len());
    }

    #[test]
    fn prices_scale_with_size_within_family() {
        let l = instance_type("m5.large").unwrap();
        let xl = instance_type("m5.xlarge").unwrap();
        let xxl = instance_type("m5.2xlarge").unwrap();
        assert!((xl.on_demand_hourly / l.on_demand_hourly - 2.0).abs() < 0.01);
        assert!((xxl.on_demand_hourly / xl.on_demand_hourly - 2.0).abs() < 0.01);
    }

    #[test]
    fn nic_bandwidth_scales_with_size_within_family() {
        let l = instance_type("m5.large").unwrap();
        let xl = instance_type("m5.xlarge").unwrap();
        let xxxxl = instance_type("m5.4xlarge").unwrap();
        assert!(l.nic_gbps < xl.nic_gbps && xl.nic_gbps < xxxxl.nic_gbps);
        for t in INSTANCE_TYPES {
            assert!(t.nic_gbps > 0.0, "{} needs a NIC", t.name);
        }
    }

    #[test]
    fn spot_base_is_big_discount() {
        for t in INSTANCE_TYPES {
            assert!(t.spot_base_fraction > 0.2 && t.spot_base_fraction < 0.5);
        }
    }
}
