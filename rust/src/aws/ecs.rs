//! Elastic Container Service: task definitions, services, bin-packing
//! placement of containers onto instances.
//!
//! Reproduced paper behaviours (Summary step 3, orange text):
//!
//! * "ECS puts Docker containers onto EC2 instances.  If there is a
//!   mismatch within your Config file and the Docker is larger than the
//!   instance it will not be placed."
//! * "ECS will keep placing Dockers onto an instance until it is full, so
//!   if you accidentally create instances that are too large you may end
//!   up with more Dockers placed on it than intended."  (Experiment T9.)
//! * Distinct clusters isolate concurrent analyses (the
//!   NuclearSegmentation_Drosophila vs _HeLa example).
//!
//! CPU is in CPU shares (1024 = one vCPU) and memory in MB, exactly the
//! units of the Config file's CPU_SHARES and MEMORY knobs.

use std::collections::HashMap;

use crate::sim::store::{IdStore, StoreKind};
use crate::sim::SimTime;

use super::ec2::{InstanceId, InstanceType};

/// Containers of shape (`cpu_shares`, `memory_mb`) that fit on one
/// instance of `ty` — the per-type bin-packing bound the scheduler
/// converges to.  With heterogeneous fleets there is no single global
/// containers-per-machine constant: every pool packs differently, and
/// `TASKS_PER_MACHINE` is only the *intent* (the paper's T9 caveat).
///
/// ```
/// use ds_rs::aws::ec2::instance_type;
/// use ds_rs::aws::ecs::containers_that_fit;
/// // 2048-share / 7.5 GB containers: an m5.xlarge fits 2 (CPU-bound),
/// // a c5.xlarge only 1 (memory-bound), an m5.large 1.
/// assert_eq!(containers_that_fit(2048, 7_500, instance_type("m5.xlarge").unwrap()), 2);
/// assert_eq!(containers_that_fit(2048, 7_500, instance_type("c5.xlarge").unwrap()), 1);
/// assert_eq!(containers_that_fit(2048, 7_500, instance_type("m5.large").unwrap()), 1);
/// ```
pub fn containers_that_fit(cpu_shares: u32, memory_mb: u64, ty: &InstanceType) -> u32 {
    let by_cpu = (ty.vcpus * 1024) / cpu_shares.max(1);
    let by_mem = u32::try_from(ty.memory_mb / memory_mb.max(1)).unwrap_or(u32::MAX);
    by_cpu.min(by_mem)
}

/// Container identifier.
pub type ContainerId = u64;

/// ECS task definition: the shape of one Docker container.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDefinition {
    pub family: String,
    /// CPU_SHARES (1024 = 1 vCPU).
    pub cpu_shares: u32,
    /// MEMORY in MB.
    pub memory_mb: u64,
    /// Environment passed to the container (DS passes its whole Config).
    pub env: Vec<(String, String)>,
}

/// An ECS service: "how many Dockers you want".
#[derive(Debug, Clone)]
pub struct Service {
    pub name: String,
    pub cluster: String,
    pub task_family: String,
    pub desired_count: u32,
}

/// A placed container.
#[derive(Debug, Clone, PartialEq)]
pub struct Container {
    pub id: ContainerId,
    pub service: String,
    pub task_family: String,
    pub instance: InstanceId,
    pub placed_at: SimTime,
    pub stopped: bool,
}

#[derive(Debug, Default)]
struct Cluster {
    /// Registered container instances (EC2 ids) in registration order.
    instances: Vec<InstanceId>,
}

/// Per-instance placement state: capacity, consumption, and the sorted
/// container index — one contiguous record per registered instance
/// (previously three parallel `HashMap`s), keeping `containers_on` /
/// `free_on` O(k) with a single id-indexed lookup.
#[derive(Debug, Default)]
struct EcsInstance {
    /// vCPU shares and memory capacity.
    cap_cpu: u32,
    cap_mem: u64,
    /// Consumed shares/memory.
    used_cpu: u32,
    used_mem: u64,
    /// Containers on this instance, ids ascending.
    containers: Vec<ContainerId>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum EcsError {
    #[error("ClusterNotFound: {0}")]
    NoSuchCluster(String),
    #[error("TaskDefinitionNotFound: {0}")]
    NoSuchTaskDef(String),
    #[error("ServiceNotFound: {0}")]
    NoSuchService(String),
}

/// The ECS control plane.
#[derive(Debug, Default)]
pub struct Ecs {
    clusters: HashMap<String, Cluster>,
    task_defs: HashMap<String, TaskDefinition>,
    services: HashMap<String, Service>,
    /// Containers by id — dense index by default (ids are sequential).
    containers: IdStore<Container>,
    /// Placement state per registered instance.
    instances: IdStore<EcsInstance>,
    /// Running container count per service (placement bookkeeping).
    per_service: HashMap<String, u32>,
    next_container: ContainerId,
}

impl Ecs {
    pub fn new() -> Self {
        Self::with_store(StoreKind::default())
    }

    /// An ECS control plane on an explicit entity-storage backend (the
    /// A/B equivalence gate runs both).
    pub fn with_store(kind: StoreKind) -> Self {
        let mut ecs = Self {
            containers: IdStore::with_kind(kind),
            instances: IdStore::with_kind(kind),
            ..Self::default()
        };
        // Every AWS account comes with a "default" cluster.
        ecs.create_cluster("default");
        ecs
    }

    pub fn create_cluster(&mut self, name: &str) {
        self.clusters.entry(name.to_string()).or_default();
    }

    /// RegisterTaskDefinition (idempotent by family: revisions collapse).
    pub fn register_task_definition(&mut self, def: TaskDefinition) {
        self.task_defs.insert(def.family.clone(), def);
    }

    pub fn task_definition(&self, family: &str) -> Option<&TaskDefinition> {
        self.task_defs.get(family)
    }

    pub fn deregister_task_definition(&mut self, family: &str) {
        self.task_defs.remove(family);
    }

    /// CreateService / UpdateService.
    pub fn create_service(&mut self, svc: Service) -> Result<(), EcsError> {
        if !self.clusters.contains_key(&svc.cluster) {
            return Err(EcsError::NoSuchCluster(svc.cluster.clone()));
        }
        if !self.task_defs.contains_key(&svc.task_family) {
            return Err(EcsError::NoSuchTaskDef(svc.task_family.clone()));
        }
        self.services.insert(svc.name.clone(), svc);
        Ok(())
    }

    /// UpdateService desiredCount (monitor downscales this to 0).
    pub fn set_desired_count(&mut self, service: &str, n: u32) -> Result<(), EcsError> {
        self.services
            .get_mut(service)
            .map(|s| s.desired_count = n)
            .ok_or_else(|| EcsError::NoSuchService(service.into()))
    }

    pub fn service(&self, name: &str) -> Option<&Service> {
        self.services.get(name)
    }

    /// DeleteService.
    pub fn delete_service(&mut self, name: &str) {
        self.services.remove(name);
        // Containers of a deleted service stop (and are dropped: stopped
        // containers are never queried again, and keeping them would make
        // placement scans O(all containers ever)).
        let victims: Vec<ContainerId> = self
            .containers
            .values()
            .filter(|c| c.service == name)
            .map(|c| c.id)
            .collect();
        for id in victims {
            self.remove_container(id);
        }
    }

    /// An EC2 instance's ECS agent comes up: join the cluster.
    pub fn register_instance(
        &mut self,
        cluster: &str,
        id: InstanceId,
        vcpus: u32,
        memory_mb: u64,
    ) -> Result<(), EcsError> {
        let c = self
            .clusters
            .get_mut(cluster)
            .ok_or_else(|| EcsError::NoSuchCluster(cluster.into()))?;
        if !c.instances.contains(&id) {
            c.instances.push(id);
        }
        // Re-registration updates capacity in place (consumption and the
        // container index survive, as with the old separate maps).
        if let Some(rec) = self.instances.get_mut(id) {
            rec.cap_cpu = vcpus * 1024;
            rec.cap_mem = memory_mb;
        } else {
            self.instances.insert(
                id,
                EcsInstance {
                    cap_cpu: vcpus * 1024,
                    cap_mem: memory_mb,
                    ..EcsInstance::default()
                },
            );
        }
        Ok(())
    }

    /// Instance died: remove from cluster, stop its containers.
    /// Returns ids of stopped containers.
    pub fn deregister_instance(&mut self, id: InstanceId) -> Vec<ContainerId> {
        for c in self.clusters.values_mut() {
            c.instances.retain(|&i| i != id);
        }
        let stopped = self
            .instances
            .remove(id)
            .map(|rec| rec.containers)
            .unwrap_or_default();
        for &cid in &stopped {
            if let Some(c) = self.containers.remove(cid) {
                if let Some(n) = self.per_service.get_mut(&c.service) {
                    *n = n.saturating_sub(1);
                }
            }
        }
        stopped
    }

    /// Drop one container record, maintaining all indexes.
    fn remove_container(&mut self, id: ContainerId) {
        let Some(c) = self.containers.remove(id) else {
            return;
        };
        if let Some(rec) = self.instances.get_mut(c.instance) {
            rec.containers.retain(|&x| x != id);
        }
        if let Some(td) = self.task_defs.get(&c.task_family) {
            if let Some(rec) = self.instances.get_mut(c.instance) {
                rec.used_cpu = rec.used_cpu.saturating_sub(td.cpu_shares);
                rec.used_mem = rec.used_mem.saturating_sub(td.memory_mb);
            }
        }
        if let Some(n) = self.per_service.get_mut(&c.service) {
            *n = n.saturating_sub(1);
        }
    }

    /// Free (cpu_shares, memory) on an instance — O(1) via the record.
    fn free_on(&self, id: InstanceId) -> (u32, u64) {
        let Some(rec) = self.instances.get(id) else {
            return (0, 0);
        };
        (
            rec.cap_cpu.saturating_sub(rec.used_cpu),
            rec.cap_mem.saturating_sub(rec.used_mem),
        )
    }

    /// The ECS scheduler pass: place containers for every service that is
    /// below its desired count, packing each registered instance until it
    /// is full.  Returns newly placed containers.
    pub fn place_tasks(&mut self, now: SimTime) -> Vec<Container> {
        let mut placed = Vec::new();
        let service_names: Vec<String> = {
            let mut v: Vec<String> = self.services.keys().cloned().collect();
            v.sort();
            v
        };
        for sname in service_names {
            let (cluster, family, desired) = {
                let s = &self.services[&sname];
                (s.cluster.clone(), s.task_family.clone(), s.desired_count)
            };
            let Some(td) = self.task_defs.get(&family).cloned() else {
                continue;
            };
            let mut running = self.per_service.get(&sname).copied().unwrap_or(0);
            if running >= desired {
                continue;
            }
            let instance_ids = self
                .clusters
                .get(&cluster)
                .map(|c| c.instances.clone())
                .unwrap_or_default();
            'outer: for iid in instance_ids {
                loop {
                    if running >= desired {
                        break 'outer;
                    }
                    let (free_cpu, free_mem) = self.free_on(iid);
                    if free_cpu < td.cpu_shares || free_mem < td.memory_mb {
                        break; // this instance is full; next one
                    }
                    self.next_container += 1;
                    let c = Container {
                        id: self.next_container,
                        service: sname.clone(),
                        task_family: family.clone(),
                        instance: iid,
                        placed_at: now,
                        stopped: false,
                    };
                    self.containers.insert(c.id, c.clone());
                    // free_on returned nonzero, so the record exists.
                    if let Some(rec) = self.instances.get_mut(iid) {
                        // Ids ascend, so push keeps the index sorted.
                        rec.containers.push(c.id);
                        rec.used_cpu += td.cpu_shares;
                        rec.used_mem += td.memory_mb;
                    }
                    *self.per_service.entry(sname.clone()).or_insert(0) += 1;
                    placed.push(c);
                    running += 1;
                }
            }
        }
        placed
    }

    /// Stop one container (worker self-stop or service scale-in).  The
    /// record is dropped immediately: its capacity frees up and it never
    /// counts toward a service again.
    pub fn stop_container(&mut self, id: ContainerId) {
        self.remove_container(id);
    }

    pub fn container(&self, id: ContainerId) -> Option<&Container> {
        self.containers.get(id)
    }

    /// Running containers on an instance, sorted by id (O(k) via index).
    pub fn containers_on(&self, id: InstanceId) -> Vec<&Container> {
        self.instances
            .get(id)
            .map(|rec| {
                rec.containers
                    .iter()
                    .filter_map(|&c| self.containers.get(c))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Running containers of a service (O(1)).
    pub fn running_count(&self, service: &str) -> u32 {
        self.per_service.get(service).copied().unwrap_or(0)
    }

    /// All resources gone?  (Monitor cleanup invariant.)
    pub fn is_clean(&self, service: &str, family: &str) -> bool {
        !self.services.contains_key(service)
            && !self.task_defs.contains_key(family)
            && self.running_count(service) == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn td(cpu: u32, mem: u64) -> TaskDefinition {
        TaskDefinition {
            family: "app".into(),
            cpu_shares: cpu,
            memory_mb: mem,
            env: vec![],
        }
    }

    fn ecs_with(cpu: u32, mem: u64, desired: u32) -> Ecs {
        let mut e = Ecs::new();
        e.register_task_definition(td(cpu, mem));
        e.create_service(Service {
            name: "app-svc".into(),
            cluster: "default".into(),
            task_family: "app".into(),
            desired_count: desired,
        })
        .unwrap();
        e
    }

    #[test]
    fn packs_until_instance_full() {
        // 4 vCPU, 16 GB instance; 1024-share 4 GB containers -> fits 4.
        let mut e = ecs_with(1024, 4_096, 10);
        e.register_instance("default", 1, 4, 16_384).unwrap();
        let placed = e.place_tasks(0);
        assert_eq!(placed.len(), 4);
        assert!(placed.iter().all(|c| c.instance == 1));
    }

    #[test]
    fn too_big_docker_never_placed() {
        // Paper: "the Docker is larger than the instance it will not be placed".
        let mut e = ecs_with(8 * 1024, 4_096, 2);
        e.register_instance("default", 1, 4, 16_384).unwrap();
        assert!(e.place_tasks(0).is_empty());
    }

    #[test]
    fn oversized_instance_gets_overpacked() {
        // Paper: intend 2 Dockers/machine but give it a 16-vCPU machine ->
        // ECS packs 16 (memory-permitting).
        let mut e = ecs_with(1024, 1_024, 100);
        e.register_instance("default", 1, 16, 65_536).unwrap();
        let placed = e.place_tasks(0);
        assert_eq!(placed.len(), 16, "ECS blindly fills the big instance");
    }

    #[test]
    fn respects_desired_count() {
        let mut e = ecs_with(1024, 2_048, 3);
        e.register_instance("default", 1, 16, 65_536).unwrap();
        assert_eq!(e.place_tasks(0).len(), 3);
        assert_eq!(e.place_tasks(1), vec![]);
        assert_eq!(e.running_count("app-svc"), 3);
    }

    #[test]
    fn memory_limits_placement() {
        // Plenty of CPU, tight memory: 16 GB / 7 GB -> 2 per machine.
        let mut e = ecs_with(256, 7_000, 10);
        e.register_instance("default", 1, 16, 16_384).unwrap();
        assert_eq!(e.place_tasks(0).len(), 2);
    }

    #[test]
    fn spreads_to_later_instances_after_fill() {
        let mut e = ecs_with(1024, 4_096, 6);
        e.register_instance("default", 1, 4, 16_384).unwrap();
        e.register_instance("default", 2, 4, 16_384).unwrap();
        let placed = e.place_tasks(0);
        assert_eq!(placed.len(), 6);
        let on1 = placed.iter().filter(|c| c.instance == 1).count();
        let on2 = placed.iter().filter(|c| c.instance == 2).count();
        assert_eq!((on1, on2), (4, 2), "fills instance 1 before spilling");
    }

    #[test]
    fn deregister_stops_containers_and_frees_slots() {
        let mut e = ecs_with(1024, 4_096, 4);
        e.register_instance("default", 1, 4, 16_384).unwrap();
        e.place_tasks(0);
        let stopped = e.deregister_instance(1);
        assert_eq!(stopped.len(), 4);
        assert_eq!(e.running_count("app-svc"), 0);
        // Replacement instance gets the containers back.
        e.register_instance("default", 2, 4, 16_384).unwrap();
        assert_eq!(e.place_tasks(1).len(), 4);
    }

    #[test]
    fn distinct_clusters_isolate_placement() {
        let mut e = Ecs::new();
        e.create_cluster("hela");
        e.register_task_definition(td(1024, 2_048));
        e.create_service(Service {
            name: "svc".into(),
            cluster: "hela".into(),
            task_family: "app".into(),
            desired_count: 4,
        })
        .unwrap();
        // Instance registered in *default*, service wants *hela* -> nothing.
        e.register_instance("default", 1, 8, 32_768).unwrap();
        assert!(e.place_tasks(0).is_empty());
        e.register_instance("hela", 2, 8, 32_768).unwrap();
        assert_eq!(e.place_tasks(1).len(), 4);
    }

    #[test]
    fn service_requires_cluster_and_taskdef() {
        let mut e = Ecs::new();
        let err = e
            .create_service(Service {
                name: "s".into(),
                cluster: "missing".into(),
                task_family: "app".into(),
                desired_count: 1,
            })
            .unwrap_err();
        assert!(matches!(err, EcsError::NoSuchCluster(_)));
        e.create_cluster("c");
        let err = e
            .create_service(Service {
                name: "s".into(),
                cluster: "c".into(),
                task_family: "app".into(),
                desired_count: 1,
            })
            .unwrap_err();
        assert!(matches!(err, EcsError::NoSuchTaskDef(_)));
    }

    #[test]
    fn containers_that_fit_matches_scheduler() {
        // The closed-form bound agrees with what place_tasks actually
        // packs, across container shapes and machine types.
        use crate::aws::ec2::instance_type;
        let shapes = [(1024u32, 2_048u64), (2048, 7_500), (4096, 15_360), (512, 1_024)];
        let machines = ["m5.large", "m5.xlarge", "m5.2xlarge", "c5.xlarge", "r5.xlarge"];
        for (cpu, mem) in shapes {
            for m in machines {
                let ty = instance_type(m).unwrap();
                let mut e = ecs_with(cpu, mem, 1_000);
                e.register_instance("default", 1, ty.vcpus, ty.memory_mb).unwrap();
                let placed = e.place_tasks(0).len() as u32;
                assert_eq!(
                    placed,
                    containers_that_fit(cpu, mem, ty),
                    "shape ({cpu}, {mem}) on {m}"
                );
            }
        }
    }

    #[test]
    fn scale_to_zero_then_delete_is_clean() {
        let mut e = ecs_with(1024, 2_048, 2);
        e.register_instance("default", 1, 4, 8_192).unwrap();
        let placed = e.place_tasks(0);
        e.set_desired_count("app-svc", 0).unwrap();
        for c in &placed {
            e.stop_container(c.id);
        }
        e.delete_service("app-svc");
        e.deregister_task_definition("app");
        assert!(e.is_clean("app-svc", "app"));
    }
}
