//! Simple Storage Service: buckets of key -> object.
//!
//! DS uses S3 four ways (paper, Online Methods): input data lives in a
//! bucket; workers download inputs and upload results; `CHECK_IF_DONE`
//! lists the output prefix and counts qualifying files; the monitor
//! exports CloudWatch logs into the bucket at the end of a run.  So the
//! simulator implements exactly: put / get / list-prefix / size metadata,
//! with request and byte accounting for the billing meter.
//!
//! Object bodies are either real bytes (PJRT inputs/outputs in the
//! end-to-end examples) or synthetic sizes (scale benchmarks that model
//! thousands of jobs without holding gigabytes in RAM).  Both carry the
//! same metadata so `CHECK_IF_DONE` logic cannot tell them apart.
//!
//! The object store itself is instantaneous; *timed* transfers (bytes
//! competing for instance NIC and bucket throughput) live in the
//! [`dataplane`] submodule and are driven by the run's event loop.

pub mod dataplane;

use std::collections::{BTreeMap, HashMap};

use crate::sim::SimTime;

/// An object body: real bytes or a size-only placeholder.
#[derive(Debug, Clone, PartialEq)]
pub enum Body {
    Bytes(Vec<u8>),
    Synthetic { size: u64 },
}

impl Body {
    pub fn len(&self) -> u64 {
        match self {
            Body::Bytes(b) => b.len() as u64,
            Body::Synthetic { size } => *size,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Real bytes, if present.
    pub fn bytes(&self) -> Option<&[u8]> {
        match self {
            Body::Bytes(b) => Some(b),
            Body::Synthetic { .. } => None,
        }
    }
}

/// A stored object.
#[derive(Debug, Clone)]
pub struct Object {
    pub body: Body,
    pub last_modified: SimTime,
}

#[derive(Debug, Default)]
struct Bucket {
    // BTreeMap: list-prefix is a range scan, like real S3's sorted keyspace.
    objects: BTreeMap<String, Object>,
}

/// Request counters for the billing meter (real S3 bills per request
/// class and per byte-month stored).
#[derive(Debug, Default, Clone, Copy)]
pub struct S3Stats {
    pub put_requests: u64,
    pub get_requests: u64,
    /// HeadObject calls: no byte transfer, but real S3 bills them in the
    /// GET request class — the data plane's size-the-input probes (one
    /// per download attempt) show up in the bill.
    pub head_requests: u64,
    pub list_requests: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// The S3 control plane: named buckets.
#[derive(Debug, Default)]
pub struct S3 {
    buckets: HashMap<String, Bucket>,
    stats: S3Stats,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum S3Error {
    #[error("NoSuchBucket: {0}")]
    NoSuchBucket(String),
    #[error("NoSuchKey: {0}")]
    NoSuchKey(String),
}

impl S3 {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a bucket (idempotent, like CreateBucket on an owned name).
    pub fn create_bucket(&mut self, name: &str) {
        self.buckets.entry(name.to_string()).or_default();
    }

    pub fn bucket_exists(&self, name: &str) -> bool {
        self.buckets.contains_key(name)
    }

    /// PutObject.
    pub fn put(
        &mut self,
        bucket: &str,
        key: &str,
        body: Body,
        now: SimTime,
    ) -> Result<(), S3Error> {
        self.stats.put_requests += 1;
        self.stats.bytes_in += body.len();
        let b = self
            .buckets
            .get_mut(bucket)
            .ok_or_else(|| S3Error::NoSuchBucket(bucket.into()))?;
        b.objects.insert(
            key.to_string(),
            Object {
                body,
                last_modified: now,
            },
        );
        Ok(())
    }

    /// GetObject.
    pub fn get(&mut self, bucket: &str, key: &str) -> Result<&Object, S3Error> {
        self.stats.get_requests += 1;
        let b = self
            .buckets
            .get(bucket)
            .ok_or_else(|| S3Error::NoSuchBucket(bucket.into()))?;
        let obj = b
            .objects
            .get(key)
            .ok_or_else(|| S3Error::NoSuchKey(key.into()))?;
        self.stats.bytes_out += obj.body.len();
        Ok(obj)
    }

    /// HeadObject: metadata without a byte transfer — but still a
    /// billable request (GET class), metered separately.
    pub fn head(&mut self, bucket: &str, key: &str) -> Option<(u64, SimTime)> {
        self.stats.head_requests += 1;
        self.buckets
            .get(bucket)?
            .objects
            .get(key)
            .map(|o| (o.body.len(), o.last_modified))
    }

    /// ListObjectsV2 with a prefix: returns (key, size) pairs in key order.
    pub fn list_prefix(&mut self, bucket: &str, prefix: &str) -> Vec<(String, u64)> {
        self.stats.list_requests += 1;
        let Some(b) = self.buckets.get(bucket) else {
            return Vec::new();
        };
        b.objects
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, o)| (k.clone(), o.body.len()))
            .collect()
    }

    /// DeleteObject (idempotent).
    pub fn delete(&mut self, bucket: &str, key: &str) {
        self.stats.put_requests += 1;
        if let Some(b) = self.buckets.get_mut(bucket) {
            b.objects.remove(key);
        }
    }

    /// Total bytes stored across all buckets (for storage billing).
    pub fn total_bytes(&self) -> u64 {
        self.buckets
            .values()
            .flat_map(|b| b.objects.values())
            .map(|o| o.body.len())
            .sum()
    }

    /// Number of objects under a prefix (cheap CHECK_IF_DONE helper).
    pub fn count_prefix(&mut self, bucket: &str, prefix: &str) -> usize {
        self.list_prefix(bucket, prefix).len()
    }

    pub fn stats(&self) -> S3Stats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s3_with_bucket() -> S3 {
        let mut s3 = S3::new();
        s3.create_bucket("data");
        s3
    }

    #[test]
    fn put_get_roundtrip() {
        let mut s3 = s3_with_bucket();
        s3.put("data", "a/b.bin", Body::Bytes(vec![1, 2, 3]), 5).unwrap();
        let obj = s3.get("data", "a/b.bin").unwrap();
        assert_eq!(obj.body.bytes(), Some(&[1u8, 2, 3][..]));
        assert_eq!(obj.last_modified, 5);
    }

    #[test]
    fn missing_bucket_and_key() {
        let mut s3 = s3_with_bucket();
        assert_eq!(
            s3.put("nope", "k", Body::Synthetic { size: 1 }, 0),
            Err(S3Error::NoSuchBucket("nope".into()))
        );
        assert!(matches!(s3.get("data", "k"), Err(S3Error::NoSuchKey(_))));
    }

    #[test]
    fn overwrite_updates_mtime_and_body() {
        let mut s3 = s3_with_bucket();
        s3.put("data", "k", Body::Synthetic { size: 10 }, 1).unwrap();
        s3.put("data", "k", Body::Synthetic { size: 20 }, 2).unwrap();
        let obj = s3.get("data", "k").unwrap();
        assert_eq!(obj.body.len(), 20);
        assert_eq!(obj.last_modified, 2);
    }

    #[test]
    fn list_prefix_sorted_and_scoped() {
        let mut s3 = s3_with_bucket();
        for k in ["out/1.csv", "out/2.csv", "out/10.csv", "other/x"] {
            s3.put("data", k, Body::Synthetic { size: 7 }, 0).unwrap();
        }
        let listed = s3.list_prefix("data", "out/");
        let keys: Vec<&str> = listed.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["out/1.csv", "out/10.csv", "out/2.csv"]);
        assert!(listed.iter().all(|&(_, sz)| sz == 7));
        assert!(s3.list_prefix("data", "missing/").is_empty());
    }

    #[test]
    fn prefix_is_string_prefix_not_dir() {
        let mut s3 = s3_with_bucket();
        s3.put("data", "out", Body::Synthetic { size: 1 }, 0).unwrap();
        s3.put("data", "out/1", Body::Synthetic { size: 1 }, 0).unwrap();
        s3.put("data", "outlier", Body::Synthetic { size: 1 }, 0).unwrap();
        assert_eq!(s3.count_prefix("data", "out"), 3);
        assert_eq!(s3.count_prefix("data", "out/"), 1);
    }

    #[test]
    fn delete_idempotent() {
        let mut s3 = s3_with_bucket();
        s3.put("data", "k", Body::Synthetic { size: 3 }, 0).unwrap();
        s3.delete("data", "k");
        s3.delete("data", "k");
        assert!(s3.get("data", "k").is_err());
    }

    #[test]
    fn stats_accumulate() {
        let mut s3 = s3_with_bucket();
        s3.put("data", "k", Body::Bytes(vec![0; 100]), 0).unwrap();
        let _ = s3.get("data", "k");
        let _ = s3.list_prefix("data", "");
        let st = s3.stats();
        assert_eq!(st.put_requests, 1);
        assert_eq!(st.get_requests, 1);
        assert_eq!(st.list_requests, 1);
        assert_eq!(st.bytes_in, 100);
        assert_eq!(st.bytes_out, 100);
    }

    #[test]
    fn head_is_metered_without_bytes() {
        let mut s3 = s3_with_bucket();
        s3.put("data", "k", Body::Bytes(vec![0; 64]), 0).unwrap();
        let before = s3.stats();
        assert_eq!(s3.head("data", "k"), Some((64, 0)));
        assert_eq!(s3.head("data", "missing"), None);
        let st = s3.stats();
        // Both probes billed, neither moved a byte.
        assert_eq!(st.head_requests, before.head_requests + 2);
        assert_eq!(st.get_requests, before.get_requests);
        assert_eq!(st.bytes_out, before.bytes_out);
    }

    #[test]
    fn total_bytes_sums_buckets() {
        let mut s3 = s3_with_bucket();
        s3.create_bucket("logs");
        s3.put("data", "a", Body::Synthetic { size: 30 }, 0).unwrap();
        s3.put("logs", "b", Body::Bytes(vec![0; 12]), 0).unwrap();
        assert_eq!(s3.total_bytes(), 42);
    }
}
