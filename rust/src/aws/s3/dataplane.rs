//! Bandwidth-aware S3 data plane: timed `GetObject`/`PutObject` flows.
//!
//! The object store in [`super`] answers every call instantly; this
//! module adds the part the paper's storage-bound workflows live and die
//! by — *moving the bytes takes time*.  Each transfer becomes a **flow**
//! competing for two capacities:
//!
//! * the **instance NIC** (per-type, from the EC2 shape sheet's
//!   `nic_gbps`), shared by every flow on that machine, and
//! * the **bucket's aggregate throughput** (from the run's
//!   [`NetProfile`]), shared by every flow touching that bucket, plus a
//!   per-request first-byte latency before any byte moves.
//!
//! Concurrent flows share each capacity **max-min fairly** (progressive
//! filling): the most contended link is found, its flows frozen at the
//! fair share, the residual headroom redistributed, repeated until every
//! flow is rate-assigned.  Rates therefore only change when a flow
//! starts, activates, finishes, or is cancelled; between those instants
//! transfers progress linearly, so the plane is a plain discrete-event
//! process on the run's integer-ms heap:
//!
//! * the driver calls [`DataPlane::start`] / [`DataPlane::cancel_instance`]
//!   as jobs and machines come and go,
//! * schedules a wake-up at [`DataPlane::next_event`], and
//! * collects finished transfers with [`DataPlane::poll`].
//!
//! Everything is deterministic: no RNG, `BTreeMap` iteration orders, and
//! f64 arithmetic in fixed order — a data-shaped sweep is bit-identical
//! at any worker-thread count.
//!
//! ```
//! use ds_rs::aws::s3::dataplane::{DataPlane, Direction, NetProfile};
//!
//! let mut plane = DataPlane::new(NetProfile::standard());
//! // One 10 MB download on instance 1 (1.25 Gbit/s NIC, uncontended):
//! // 30 ms first byte, then 10e6 B / 156250 B-per-ms = 64 ms on the wire.
//! let flow = plane.start(0, 1, 1.25, "ds-data", Direction::Download, 10_000_000);
//! assert_eq!(plane.next_event(), Some(30)); // first byte arrives
//! assert!(plane.poll(30).is_empty());       // …but nothing finished yet
//! let eta = plane.next_event().unwrap();
//! assert_eq!(eta, 30 + 64);
//! let done = plane.poll(eta);
//! assert_eq!(done.len(), 1);
//! assert_eq!(done[0].0, flow);
//! assert_eq!(plane.stats().bytes_downloaded, 10_000_000);
//! ```

use std::collections::{BTreeMap, BTreeSet};

use crate::sim::SimTime;

/// Identifier of one in-flight transfer.
pub type FlowId = u64;

/// A flow below this many bytes remaining is complete (absorbs f64
/// accumulation error; sub-byte residue is physically meaningless).
const EPS_BYTES: f64 = 0.5;

/// 1 Gbit/s in bytes per simulated millisecond.
pub fn gbps_to_bytes_per_ms(gbps: f64) -> f64 {
    gbps * 125_000.0
}

/// Transfer direction, from the worker's point of view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// `GetObject`: S3 → instance.
    Download,
    /// `PutObject`: instance → S3.
    Upload,
}

/// Named network profile: the S3 side of the pipe.  The NIC side comes
/// per-instance from the EC2 shape sheet.
#[derive(Debug, Clone, PartialEq)]
pub struct NetProfile {
    /// Stable name (also the sweep-axis label).
    pub name: &'static str,
    /// Aggregate throughput budget per bucket, Gbit/s.
    pub bucket_gbps: f64,
    /// Per-request first-byte latency, ms (request fan-out tax).
    pub first_byte_ms: SimTime,
}

impl Default for NetProfile {
    fn default() -> Self {
        Self::standard()
    }
}

impl NetProfile {
    /// A healthy regional bucket: 10 Gbit/s aggregate, 30 ms first byte.
    pub const fn standard() -> Self {
        Self { name: "standard", bucket_gbps: 10.0, first_byte_ms: 30 }
    }

    /// Prefix-sharded / CloudFront-fronted bucket: 40 Gbit/s, 15 ms.
    pub const fn wide() -> Self {
        Self { name: "wide", bucket_gbps: 40.0, first_byte_ms: 15 }
    }

    /// A cold, unsharded prefix: 1 Gbit/s aggregate, 60 ms first byte —
    /// the profile that makes fleets storage-bound (experiment T13).
    pub const fn narrow() -> Self {
        Self { name: "narrow", bucket_gbps: 1.0, first_byte_ms: 60 }
    }

    /// Every named profile, widest first.
    pub const ALL: [NetProfile; 3] = [Self::wide(), Self::standard(), Self::narrow()];

    /// Parse a profile name (the `--net-profile` axis).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "standard" => Some(Self::standard()),
            "wide" => Some(Self::wide()),
            "narrow" => Some(Self::narrow()),
            _ => None,
        }
    }

    /// Bucket budget in bytes per simulated millisecond.
    pub fn bucket_bytes_per_ms(&self) -> f64 {
        gbps_to_bytes_per_ms(self.bucket_gbps)
    }
}

/// Byte, request, and bottleneck-attribution counters; feeds the billing
/// meter and the end-of-run [`DataBreakdown`](crate::aws::billing::DataBreakdown).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct TransferStats {
    /// Bytes that actually flowed S3 → fleet (full completed flows plus
    /// the partial progress of cancelled ones — exactly what egress
    /// billing sees).
    pub bytes_downloaded: u64,
    /// Bytes that actually flowed fleet → S3.
    pub bytes_uploaded: u64,
    /// The slice of the above that was thrown away: transfers cancelled
    /// mid-flight by interruption / crash / reaping (the re-download tax).
    pub bytes_wasted: u64,
    /// `GetObject` requests issued by the data plane.
    pub downloads_started: u64,
    /// `PutObject` requests issued by the data plane.
    pub uploads_started: u64,
    /// The slice of `bytes_downloaded` that moved over *peer* links
    /// (node-local / shared-filesystem artifact sharing, DESIGN.md §11)
    /// rather than S3 — exempt from egress and request billing.
    pub peer_bytes_downloaded: u64,
    /// The slice of `bytes_uploaded` that moved over peer links.
    pub peer_bytes_uploaded: u64,
    /// Peer transfers begun (no GET/PUT request is billed for these).
    pub peer_flows_started: u64,
    pub flows_completed: u64,
    pub flows_cancelled: u64,
    /// Flow-milliseconds where the *bucket* budget was the binding
    /// constraint — the storage-bound signal.
    pub bucket_bound_ms: u64,
    /// Flow-milliseconds where the instance NIC was the binding constraint.
    pub nic_bound_ms: u64,
    /// Flow-milliseconds spent waiting on first-byte latency.
    pub first_byte_wait_ms: u64,
}

/// What [`DataPlane::poll`] reports about a finished flow.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowEnd {
    pub instance: u64,
    pub dir: Direction,
    pub bytes: u64,
    pub bucket: String,
}

#[derive(Debug, Clone)]
struct Flow {
    instance: u64,
    nic_bytes_per_ms: f64,
    bucket: String,
    dir: Direction,
    bytes: u64,
    remaining: f64,
    /// First byte arrives here; the flow consumes no bandwidth before.
    active_at: SimTime,
    /// Bytes/ms under the current plan (0 while latent).
    rate: f64,
    /// Which link froze this flow in the current plan.
    bucket_bound: bool,
    /// Peer-class flow: shares bandwidth like any other, but bills no
    /// S3 request and no egress (the "bucket" is a peer link name).
    peer: bool,
}

/// A capacity constraint in the fairness plan.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
enum Link {
    Nic(u64),
    Bucket(String),
}

/// The transfer scheduler.  Passive like every other service: the run's
/// event loop advances it by calling [`poll`](Self::poll) at the times
/// [`next_event`](Self::next_event) announces.
#[derive(Debug)]
pub struct DataPlane {
    profile: NetProfile,
    flows: BTreeMap<FlowId, Flow>,
    /// Completed flows awaiting collection by `poll`.
    finished: Vec<(FlowId, FlowEnd)>,
    next_id: FlowId,
    /// Internal clock: the last instant flows were progressed to.
    clock: SimTime,
    stats: TransferStats,
    /// Per-bucket throughput multipliers (correlated throttling events,
    /// DESIGN.md §12); absent buckets run at the profile's full budget.
    bucket_factor: BTreeMap<String, f64>,
    /// Extra first-byte latency per instance (cross-region requests pay
    /// an additional round trip); absent instances pay none.
    first_byte_penalty: BTreeMap<u64, SimTime>,
}

impl Default for DataPlane {
    fn default() -> Self {
        Self::new(NetProfile::default())
    }
}

impl DataPlane {
    pub fn new(profile: NetProfile) -> Self {
        Self {
            profile,
            flows: BTreeMap::new(),
            finished: Vec::new(),
            next_id: 0,
            clock: 0,
            stats: TransferStats::default(),
            bucket_factor: BTreeMap::new(),
            first_byte_penalty: BTreeMap::new(),
        }
    }

    pub fn profile(&self) -> &NetProfile {
        &self.profile
    }

    /// Swap the profile (before the run starts flows).
    pub fn set_profile(&mut self, profile: NetProfile) {
        self.profile = profile;
    }

    /// Scale one bucket's aggregate throughput by `factor` (a correlated
    /// throttling event: `factor < 1` slows it, `1.0` restores it).  The
    /// change takes effect immediately — in-flight flows are progressed
    /// to `now` and re-planned under the new budget.  The factor is
    /// floored at a tiny positive rate so throttled flows still converge.
    pub fn set_bucket_factor(&mut self, now: SimTime, bucket: &str, factor: f64) {
        self.progress(now);
        if (factor - 1.0).abs() < f64::EPSILON {
            self.bucket_factor.remove(bucket);
        } else {
            self.bucket_factor.insert(bucket.to_string(), factor.max(1e-6));
        }
        self.replan();
    }

    /// Add `penalty_ms` of extra first-byte latency to every *future*
    /// flow started by `instance` (the cross-region request tax; zero
    /// clears it).  In-flight flows keep their original activation time.
    pub fn set_instance_penalty(&mut self, instance: u64, penalty_ms: SimTime) {
        if penalty_ms == 0 {
            self.first_byte_penalty.remove(&instance);
        } else {
            self.first_byte_penalty.insert(instance, penalty_ms);
        }
    }

    /// Begin a transfer of `bytes` between `instance` (whose NIC runs at
    /// `nic_gbps`) and `bucket`.  The request's first byte arrives after
    /// the profile latency; the byte flow then shares capacity max-min
    /// fairly with every concurrent flow.  Bills one GET/PUT request.
    pub fn start(
        &mut self,
        now: SimTime,
        instance: u64,
        nic_gbps: f64,
        bucket: &str,
        dir: Direction,
        bytes: u64,
    ) -> FlowId {
        match dir {
            Direction::Download => self.stats.downloads_started += 1,
            Direction::Upload => self.stats.uploads_started += 1,
        }
        self.start_flow(now, instance, nic_gbps, bucket, dir, bytes, false)
    }

    /// Begin a *peer* transfer: same bandwidth sharing and first-byte
    /// latency as [`start`](Self::start), but `link` is a peer link name
    /// (e.g. `node:split` or `fs:shared`, each with the profile's full
    /// bucket budget), not an S3 bucket — no GET/PUT request is billed
    /// and the bytes are exempt from egress.  Used by the workflow
    /// scheduler's node-local and shared-fs sharing modes (DESIGN.md §11).
    pub fn start_peer(
        &mut self,
        now: SimTime,
        instance: u64,
        nic_gbps: f64,
        link: &str,
        dir: Direction,
        bytes: u64,
    ) -> FlowId {
        self.stats.peer_flows_started += 1;
        self.start_flow(now, instance, nic_gbps, link, dir, bytes, true)
    }

    #[allow(clippy::too_many_arguments)]
    fn start_flow(
        &mut self,
        now: SimTime,
        instance: u64,
        nic_gbps: f64,
        bucket: &str,
        dir: Direction,
        bytes: u64,
        peer: bool,
    ) -> FlowId {
        self.progress(now);
        self.next_id += 1;
        let id = self.next_id;
        let penalty = self.first_byte_penalty.get(&instance).copied().unwrap_or(0);
        self.flows.insert(
            id,
            Flow {
                instance,
                nic_bytes_per_ms: gbps_to_bytes_per_ms(nic_gbps),
                bucket: bucket.to_string(),
                dir,
                bytes,
                remaining: bytes as f64,
                active_at: now.saturating_add(self.profile.first_byte_ms).saturating_add(penalty),
                rate: 0.0,
                bucket_bound: false,
                peer,
            },
        );
        self.replan();
        id
    }

    /// Progress every flow to `now` and collect the ones that finished at
    /// or before it, in completion order (FIFO within an instant).
    pub fn poll(&mut self, now: SimTime) -> Vec<(FlowId, FlowEnd)> {
        let mut out = Vec::new();
        self.poll_into(now, &mut out);
        out
    }

    /// Allocation-free [`poll`](Self::poll): appends completions to
    /// `out` instead of returning a fresh `Vec`.  The driver's net tick
    /// reuses one scratch buffer across the whole run.
    pub fn poll_into(&mut self, now: SimTime, out: &mut Vec<(FlowId, FlowEnd)>) {
        self.progress(now);
        out.append(&mut self.finished);
    }

    /// When the plane next needs attention: completions already awaiting
    /// collection (a `start`/`cancel_instance` call may progress past
    /// another flow's finish — those report "now"), else the earliest
    /// activation or completion under the current plan.  `None` when idle.
    pub fn next_event(&self) -> Option<SimTime> {
        if !self.finished.is_empty() {
            return Some(self.clock);
        }
        self.flows
            .values()
            .filter_map(|f| self.flow_boundary(f))
            .min()
    }

    /// The next instant `f` changes state: activation, or completion at
    /// the current rate.
    fn flow_boundary(&self, f: &Flow) -> Option<SimTime> {
        if f.active_at > self.clock {
            return Some(f.active_at);
        }
        if f.remaining <= EPS_BYTES {
            // Completed but not yet collected: boundary is "now".
            return Some(self.clock);
        }
        if f.rate <= 0.0 {
            return None; // unplanned (cannot happen with positive caps)
        }
        let dt = ((f.remaining / f.rate).ceil() as SimTime).max(1);
        Some(self.clock.saturating_add(dt))
    }

    /// Abort every flow on `instance` (spot interruption, crash, alarm
    /// reaping, downscale).  Bytes already flowed stay billed and are
    /// additionally counted as wasted.  Returns the cancelled flow ids.
    pub fn cancel_instance(&mut self, now: SimTime, instance: u64) -> Vec<FlowId> {
        self.progress(now);
        let ids: Vec<FlowId> = self
            .flows
            .iter()
            .filter(|(_, f)| f.instance == instance)
            .map(|(&id, _)| id)
            .collect();
        for id in &ids {
            let f = self.flows.remove(id).expect("cancelling a listed flow");
            let flowed = (f.bytes as f64 - f.remaining).clamp(0.0, f.bytes as f64).round() as u64;
            self.credit(f.dir, f.peer, flowed);
            self.stats.bytes_wasted += flowed;
            self.stats.flows_cancelled += 1;
        }
        if !ids.is_empty() {
            self.replan();
        }
        ids
    }

    /// Instances that currently have at least one flow, ascending.
    pub fn instances_with_flows(&self) -> Vec<u64> {
        let mut out = Vec::new();
        self.instances_with_flows_into(&mut out);
        out
    }

    /// Allocation-free [`instances_with_flows`](Self::instances_with_flows):
    /// clears and refills `out` (ascending, deduplicated) without an
    /// intermediate set.
    pub fn instances_with_flows_into(&self, out: &mut Vec<u64>) {
        out.clear();
        out.extend(self.flows.values().map(|f| f.instance));
        out.sort_unstable();
        out.dedup();
    }

    /// Flows currently in the plane (latent + active).
    pub fn in_flight(&self) -> usize {
        self.flows.len()
    }

    /// Current planned rate of a flow in bytes/ms (0 while latent),
    /// `None` once finished.  Exposed for the fairness property tests.
    pub fn rate_of(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.rate)
    }

    pub fn stats(&self) -> TransferStats {
        self.stats
    }

    /// Internal clock (last progressed instant).
    pub fn clock(&self) -> SimTime {
        self.clock
    }

    fn credit(&mut self, dir: Direction, peer: bool, bytes: u64) {
        match dir {
            Direction::Download => {
                self.stats.bytes_downloaded += bytes;
                if peer {
                    self.stats.peer_bytes_downloaded += bytes;
                }
            }
            Direction::Upload => {
                self.stats.bytes_uploaded += bytes;
                if peer {
                    self.stats.peer_bytes_uploaded += bytes;
                }
            }
        }
    }

    /// Advance flows to `to`, segment by segment: rates are constant
    /// between boundaries (activations/completions), so each segment is
    /// linear.  Robust to callers that jump past several boundaries.
    fn progress(&mut self, to: SimTime) {
        while self.clock < to {
            let boundary = self
                .flows
                .values()
                .filter_map(|f| self.flow_boundary(f))
                .min()
                .map_or(to, |b| b.min(to));
            let dt = boundary - self.clock;
            if dt > 0 {
                for f in self.flows.values_mut() {
                    if f.active_at > self.clock {
                        self.stats.first_byte_wait_ms += dt;
                        continue;
                    }
                    f.remaining -= f.rate * dt as f64;
                    if f.bucket_bound {
                        self.stats.bucket_bound_ms += dt;
                    } else {
                        self.stats.nic_bound_ms += dt;
                    }
                }
                self.clock = boundary;
            }
            // Collect completions at the boundary, then re-plan iff the
            // boundary actually changed the active set (a completion or
            // an activation) — a final partial segment that merely ran
            // the clock out needs no new plan.
            let activated = self.flows.values().any(|f| f.active_at == self.clock);
            let done: Vec<FlowId> = self
                .flows
                .iter()
                .filter(|(_, f)| f.active_at <= self.clock && f.remaining <= EPS_BYTES)
                .map(|(&id, _)| id)
                .collect();
            let completed_any = !done.is_empty();
            for id in done {
                let f = self.flows.remove(&id).expect("completing a listed flow");
                self.credit(f.dir, f.peer, f.bytes);
                self.stats.flows_completed += 1;
                self.finished.push((
                    id,
                    FlowEnd {
                        instance: f.instance,
                        dir: f.dir,
                        bytes: f.bytes,
                        bucket: f.bucket,
                    },
                ));
            }
            if activated || completed_any {
                self.replan();
            }
        }
    }

    /// Max-min fair rate assignment (progressive filling): repeatedly
    /// find the most contended link (smallest capacity / unfrozen-flow
    /// count), freeze its flows at that fair share, subtract the share
    /// from each flow's *other* link, and drop the saturated link.
    fn replan(&mut self) {
        for f in self.flows.values_mut() {
            f.rate = 0.0;
        }
        let active: Vec<FlowId> = self
            .flows
            .iter()
            .filter(|(_, f)| f.active_at <= self.clock && f.remaining > EPS_BYTES)
            .map(|(&id, _)| id)
            .collect();
        if active.is_empty() {
            return;
        }
        let bucket_cap = self.profile.bucket_bytes_per_ms();
        let mut cap: BTreeMap<Link, f64> = BTreeMap::new();
        let mut members: BTreeMap<Link, Vec<FlowId>> = BTreeMap::new();
        for &id in &active {
            let f = &self.flows[&id];
            let factor = self.bucket_factor.get(&f.bucket).copied().unwrap_or(1.0);
            cap.entry(Link::Nic(f.instance)).or_insert(f.nic_bytes_per_ms);
            cap.entry(Link::Bucket(f.bucket.clone())).or_insert(bucket_cap * factor);
            members.entry(Link::Nic(f.instance)).or_default().push(id);
            members.entry(Link::Bucket(f.bucket.clone())).or_default().push(id);
        }
        let mut unfrozen: BTreeSet<FlowId> = active.iter().copied().collect();
        while !unfrozen.is_empty() {
            // Bottleneck link: minimal fair share; ties break on link key
            // so the plan is a pure function of the flow set.
            let mut best: Option<(f64, Link)> = None;
            for (link, m) in &members {
                let n = m.iter().filter(|id| unfrozen.contains(*id)).count();
                if n == 0 {
                    continue;
                }
                let share = (cap[link] / n as f64).max(0.0);
                let better = match &best {
                    None => true,
                    Some((s, l)) => share < *s || (share == *s && link < l),
                };
                if better {
                    best = Some((share, link.clone()));
                }
            }
            let Some((share, link)) = best else { break };
            let ids: Vec<FlowId> = members[&link]
                .iter()
                .filter(|id| unfrozen.contains(*id))
                .copied()
                .collect();
            for id in ids {
                let (other, from_bucket) = {
                    let f = &self.flows[&id];
                    match link {
                        Link::Bucket(_) => (Link::Nic(f.instance), true),
                        Link::Nic(_) => (Link::Bucket(f.bucket.clone()), false),
                    }
                };
                let f = self.flows.get_mut(&id).expect("planning a listed flow");
                f.rate = share;
                f.bucket_bound = from_bucket;
                if let Some(c) = cap.get_mut(&other) {
                    *c = (*c - share).max(0.0);
                }
                unfrozen.remove(&id);
            }
            cap.remove(&link);
            members.remove(&link);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1.25 Gbit/s NIC = 156 250 bytes/ms.
    const NIC: f64 = 1.25;

    fn drain(plane: &mut DataPlane) -> Vec<(FlowId, FlowEnd)> {
        let mut all = Vec::new();
        while let Some(t) = plane.next_event() {
            all.extend(plane.poll(t));
        }
        all
    }

    #[test]
    fn single_flow_latency_plus_wire_time() {
        let mut p = DataPlane::new(NetProfile::standard());
        // 1 562 500 bytes at 156 250 B/ms = 10 ms wire + 30 ms latency.
        let id = p.start(0, 1, NIC, "b", Direction::Download, 1_562_500);
        assert_eq!(p.next_event(), Some(30));
        assert!(p.poll(30).is_empty(), "activation is not completion");
        assert_eq!(p.next_event(), Some(40));
        let done = p.poll(40);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0, id);
        assert_eq!(done[0].1.bytes, 1_562_500);
        assert_eq!(p.in_flight(), 0);
        assert_eq!(p.next_event(), None);
        let st = p.stats();
        assert_eq!(st.bytes_downloaded, 1_562_500);
        assert_eq!(st.first_byte_wait_ms, 30);
        assert_eq!(st.nic_bound_ms, 10, "uncontended NIC binds before a 10 Gbit bucket");
    }

    #[test]
    fn two_flows_share_one_nic_fairly() {
        let mut p = DataPlane::new(NetProfile::wide());
        let a = p.start(0, 1, NIC, "b", Direction::Download, 10_000_000);
        let b = p.start(0, 1, NIC, "b", Direction::Upload, 10_000_000);
        p.poll(NetProfile::wide().first_byte_ms); // both activate
        let half = gbps_to_bytes_per_ms(NIC) / 2.0;
        assert!((p.rate_of(a).unwrap() - half).abs() < 1e-9);
        assert!((p.rate_of(b).unwrap() - half).abs() < 1e-9);
        let done = drain(&mut p);
        assert_eq!(done.len(), 2);
        let st = p.stats();
        assert_eq!(st.bytes_downloaded, 10_000_000);
        assert_eq!(st.bytes_uploaded, 10_000_000);
    }

    #[test]
    fn bucket_binds_across_instances() {
        // narrow bucket: 125 000 B/ms shared by flows on 4 distinct NICs.
        let mut p = DataPlane::new(NetProfile::narrow());
        let ids: Vec<FlowId> = (0..4)
            .map(|i| p.start(0, i, NIC, "b", Direction::Download, 1_000_000))
            .collect();
        p.poll(NetProfile::narrow().first_byte_ms);
        let share = gbps_to_bytes_per_ms(1.0) / 4.0;
        for id in &ids {
            assert!((p.rate_of(*id).unwrap() - share).abs() < 1e-9);
        }
        drain(&mut p);
        let st = p.stats();
        assert!(st.bucket_bound_ms > 0);
        assert_eq!(st.nic_bound_ms, 0, "the bucket, not any NIC, was binding");
    }

    #[test]
    fn leftover_headroom_goes_to_uncontended_flows() {
        // Instance 1 runs three flows, instance 2 one; bucket is wide.
        // Max-min: instance-1 flows get cap/3, instance-2 flow its full NIC.
        let mut p = DataPlane::new(NetProfile::wide());
        let crowded: Vec<FlowId> = (0..3)
            .map(|_| p.start(0, 1, NIC, "b", Direction::Download, 5_000_000))
            .collect();
        let lone = p.start(0, 2, NIC, "b", Direction::Download, 5_000_000);
        p.poll(NetProfile::wide().first_byte_ms);
        let nic = gbps_to_bytes_per_ms(NIC);
        for id in &crowded {
            assert!((p.rate_of(*id).unwrap() - nic / 3.0).abs() < 1e-9);
        }
        assert!((p.rate_of(lone).unwrap() - nic).abs() < 1e-9);
    }

    #[test]
    fn cancel_bills_partial_bytes_as_wasted() {
        let mut p = DataPlane::new(NetProfile::standard());
        let _ = p.start(0, 7, NIC, "b", Direction::Download, 10_000_000);
        // 30 ms latency, then 20 ms of wire time at 156 250 B/ms.
        let cancelled = p.cancel_instance(50, 7);
        assert_eq!(cancelled.len(), 1);
        let st = p.stats();
        assert_eq!(st.bytes_downloaded, 3_125_000);
        assert_eq!(st.bytes_wasted, 3_125_000);
        assert_eq!(st.flows_cancelled, 1);
        assert_eq!(st.flows_completed, 0);
        assert_eq!(p.next_event(), None);
    }

    #[test]
    fn completions_are_exact_and_fifo_within_an_instant() {
        let mut p = DataPlane::new(NetProfile::standard());
        let a = p.start(0, 1, NIC, "b", Direction::Download, 1_000_000);
        let b = p.start(0, 1, NIC, "b", Direction::Download, 1_000_000);
        // Same size, same NIC, same start: they finish together, and the
        // earlier-started flow is reported first.
        let done = drain(&mut p);
        assert_eq!(done.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![a, b]);
        let st = p.stats();
        assert_eq!(st.bytes_downloaded, 2_000_000);
        assert_eq!(st.flows_completed, 2);
    }

    #[test]
    fn completions_buffered_by_a_later_start_are_reported_now() {
        let mut p = DataPlane::new(NetProfile::standard());
        // A finishes at 40; the start() at t=100 progresses past that
        // boundary, so A waits in the collection buffer — next_event
        // must say "now", not go quiet.
        let a = p.start(0, 1, NIC, "b", Direction::Download, 1_562_500);
        let _b = p.start(100, 2, NIC, "b", Direction::Download, 1_562_500);
        assert_eq!(p.next_event(), Some(100));
        let done = p.poll(100);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0, a);
        // And the plane goes back to planned boundaries afterwards.
        assert_eq!(p.next_event(), Some(140));
    }

    #[test]
    fn staggered_arrival_replans_mid_flow() {
        let mut p = DataPlane::new(NetProfile::wide());
        // Flow A alone for a while, then B joins the same NIC: A's total
        // time is strictly between the solo and the always-shared case.
        let solo_ms = (10_000_000.0 / gbps_to_bytes_per_ms(NIC)).ceil() as SimTime;
        let a = p.start(0, 1, NIC, "b", Direction::Download, 10_000_000);
        let _b = p.start(20, 1, NIC, "b", Direction::Download, 10_000_000);
        let mut a_done_at = 0;
        while let Some(t) = p.next_event() {
            for (id, _) in p.poll(t) {
                if id == a {
                    a_done_at = t;
                }
            }
        }
        let first_byte = NetProfile::wide().first_byte_ms;
        assert!(a_done_at > first_byte + solo_ms, "sharing must slow A down");
        assert!(a_done_at < first_byte + 2 * solo_ms, "A had a head start");
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut p = DataPlane::new(NetProfile::standard());
            for i in 0..20u64 {
                p.start(
                    i * 3,
                    i % 4,
                    NIC,
                    if i % 2 == 0 { "a" } else { "b" },
                    if i % 3 == 0 { Direction::Upload } else { Direction::Download },
                    1 + i * 777_777,
                );
            }
            let mut trace = Vec::new();
            while let Some(t) = p.next_event() {
                for (id, end) in p.poll(t) {
                    trace.push((t, id, end.bytes));
                }
            }
            (trace, p.stats())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn peer_flows_move_bytes_without_requests() {
        let mut p = DataPlane::new(NetProfile::standard());
        // A peer pull from a producer's node link: same physics...
        let id = p.start_peer(0, 1, NIC, "node:split", Direction::Download, 1_562_500);
        assert_eq!(p.next_event(), Some(30));
        assert_eq!(p.poll(40).len(), 1);
        assert_eq!(p.rate_of(id), None);
        let st = p.stats();
        // ...same byte totals, but flagged peer and request-free.
        assert_eq!(st.bytes_downloaded, 1_562_500);
        assert_eq!(st.peer_bytes_downloaded, 1_562_500);
        assert_eq!(st.downloads_started, 0);
        assert_eq!(st.peer_flows_started, 1);
        assert_eq!(st.flows_completed, 1);
    }

    #[test]
    fn cancelled_peer_flow_credits_partial_peer_bytes() {
        let mut p = DataPlane::new(NetProfile::standard());
        let _ = p.start_peer(0, 7, NIC, "fs:shared", Direction::Upload, 10_000_000);
        // 30 ms latency + 20 ms of wire at 156 250 B/ms.
        assert_eq!(p.cancel_instance(50, 7).len(), 1);
        let st = p.stats();
        assert_eq!(st.bytes_uploaded, 3_125_000);
        assert_eq!(st.peer_bytes_uploaded, 3_125_000);
        assert_eq!(st.bytes_wasted, 3_125_000);
        assert_eq!(st.uploads_started, 0);
    }

    #[test]
    fn peer_links_have_their_own_bandwidth_budget() {
        // Two flows on distinct peer links and distinct NICs never
        // contend; on the *same* link they share it like a bucket.
        let mut p = DataPlane::new(NetProfile::narrow()); // 125 000 B/ms links
        let a = p.start_peer(0, 1, NIC, "node:a", Direction::Download, 1_000_000);
        let b = p.start_peer(0, 2, NIC, "node:b", Direction::Download, 1_000_000);
        let c = p.start_peer(0, 3, NIC, "node:b", Direction::Download, 1_000_000);
        p.poll(NetProfile::narrow().first_byte_ms);
        let link = gbps_to_bytes_per_ms(1.0);
        assert!((p.rate_of(a).unwrap() - link).abs() < 1e-9, "a is alone on node:a");
        assert!((p.rate_of(b).unwrap() - link / 2.0).abs() < 1e-9);
        assert!((p.rate_of(c).unwrap() - link / 2.0).abs() < 1e-9);
    }

    #[test]
    fn bucket_throttle_scales_the_budget_and_clears() {
        // narrow bucket: 125 000 B/ms; throttled to 0.25 it paces one
        // flow at 31 250 B/ms even though the NIC could do 156 250.
        let mut p = DataPlane::new(NetProfile::narrow());
        let id = p.start(0, 1, NIC, "b", Direction::Download, 10_000_000);
        p.set_bucket_factor(0, "b", 0.25);
        p.poll(NetProfile::narrow().first_byte_ms);
        let quarter = gbps_to_bytes_per_ms(1.0) / 4.0;
        assert!((p.rate_of(id).unwrap() - quarter).abs() < 1e-9);
        // Restoring to 1.0 drops the override and re-plans immediately.
        p.set_bucket_factor(p.clock(), "b", 1.0);
        assert!((p.rate_of(id).unwrap() - gbps_to_bytes_per_ms(1.0)).abs() < 1e-9);
        // Other buckets were never affected.
        let other = p.start(p.clock(), 2, NIC, "c", Direction::Download, 1_000_000);
        p.poll(p.clock() + NetProfile::narrow().first_byte_ms);
        assert!((p.rate_of(other).unwrap() - gbps_to_bytes_per_ms(1.0)).abs() < 1e-9);
    }

    #[test]
    fn instance_penalty_delays_the_first_byte_of_new_flows_only() {
        let mut p = DataPlane::new(NetProfile::standard());
        let a = p.start(0, 1, NIC, "b", Direction::Download, 1_562_500);
        p.set_instance_penalty(2, 70);
        let b = p.start(0, 2, NIC, "b", Direction::Download, 1_562_500);
        // a: 30 ms latency + 10 ms wire; b: 100 ms latency + 10 ms wire.
        let done = drain(&mut p);
        assert_eq!(done.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![a, b]);
        assert_eq!(p.stats().first_byte_wait_ms, 30 + 100);
        // Zero clears the penalty.
        p.set_instance_penalty(2, 0);
        let _ = p.start(p.clock(), 2, NIC, "b", Direction::Download, 1_562_500);
        let t0 = p.clock();
        assert_eq!(p.next_event(), Some(t0 + 30));
    }

    #[test]
    fn allocation_free_variants_match_the_allocating_apis() {
        let run = |scratch: bool| {
            let mut p = DataPlane::new(NetProfile::standard());
            let mut done: Vec<(FlowId, FlowEnd)> = Vec::new();
            let mut busy: Vec<u64> = Vec::new();
            let mut trace = Vec::new();
            for i in 0..12u64 {
                p.start(i * 5, i % 3, NIC, "b", Direction::Download, 1 + i * 400_000);
                if scratch {
                    p.instances_with_flows_into(&mut busy);
                } else {
                    busy = p.instances_with_flows();
                }
                trace.push(busy.clone());
            }
            while let Some(t) = p.next_event() {
                if scratch {
                    p.poll_into(t, &mut done);
                } else {
                    done.extend(p.poll(t));
                }
            }
            trace.push(done.iter().map(|(id, _)| *id).collect());
            (trace, done, p.stats())
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn profile_parse_roundtrip() {
        for prof in NetProfile::ALL {
            assert_eq!(NetProfile::parse(prof.name), Some(prof.clone()));
        }
        assert_eq!(NetProfile::parse("adsl"), None);
        assert_eq!(NetProfile::default(), NetProfile::standard());
    }
}
