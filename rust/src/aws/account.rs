//! One AWS account: all five services plus billing inputs, built from a
//! seed and a market volatility preset.

use crate::sim::{SimRng, SimTime, StoreKind};

use super::billing::{compute_report, CostReport};
use super::cloudwatch::{Alarms, Logs, Metrics};
use super::ec2::{Ec2, SpotMarket, Volatility};
use super::ecs::Ecs;
use super::s3::dataplane::{DataPlane, NetProfile};
use super::s3::S3;
use super::sqs::Sqs;

/// Everything `aws configure` would point at.
pub struct AwsAccount {
    pub s3: S3,
    pub sqs: Sqs,
    pub ec2: Ec2,
    pub ecs: Ecs,
    pub metrics: Metrics,
    pub alarms: Alarms,
    pub logs: Logs,
    /// Timed S3 transfers (the bandwidth-aware data plane).
    pub net: DataPlane,
    /// Integrated GB-hours of S3 storage (sampled by the event loop).
    pub s3_gb_hours: f64,
    last_storage_sample: SimTime,
}

impl AwsAccount {
    pub fn new(seed: u64, vol: Volatility) -> Self {
        Self::with_store(seed, vol, StoreKind::default())
    }

    /// An account with an explicit entity-storage backend for EC2/ECS —
    /// the A/B equivalence gate builds one of each and asserts the
    /// resulting runs are bit-identical.  RNG consumption order is
    /// independent of `kind`.
    pub fn with_store(seed: u64, vol: Volatility, kind: StoreKind) -> Self {
        let mut root = SimRng::new(seed);
        let market = SpotMarket::new(root.next_u64(), vol);
        let ec2 = Ec2::with_store(market, root.fork(0xEC2), kind);
        Self {
            s3: S3::new(),
            sqs: Sqs::new(),
            ec2,
            ecs: Ecs::with_store(kind),
            metrics: Metrics::new(),
            alarms: Alarms::new(),
            logs: Logs::new(),
            net: DataPlane::new(NetProfile::default()),
            s3_gb_hours: 0.0,
            last_storage_sample: 0,
        }
    }

    /// Integrate storage usage up to `now` (call periodically + at end).
    pub fn sample_storage(&mut self, now: SimTime) {
        if now <= self.last_storage_sample {
            return;
        }
        let hours = (now - self.last_storage_sample) as f64 / crate::sim::HOUR as f64;
        let gb = self.s3.total_bytes() as f64 / 1e9;
        self.s3_gb_hours += gb * hours;
        self.last_storage_sample = now;
    }

    /// Full itemized cost report as of `now`.
    pub fn cost_report(&mut self, now: SimTime) -> CostReport {
        self.sample_storage(now);
        let accrued = self.ec2.accrued_cost_of_active(now);
        compute_report(
            self.ec2.cost_log(),
            accrued,
            self.sqs.total_requests(),
            self.s3.stats(),
            self.s3_gb_hours,
            self.metrics.put_count(),
            self.net.stats(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aws::s3::Body;
    use crate::sim::HOUR;

    #[test]
    fn account_composes_services() {
        let mut acct = AwsAccount::new(42, Volatility::Medium);
        acct.s3.create_bucket("b");
        acct.s3
            .put("b", "k", Body::Synthetic { size: 2_000_000_000 }, 0)
            .unwrap();
        acct.sqs.create_queue("q", 60_000);
        acct.sqs.send("q", "job", 0).unwrap();
        acct.sample_storage(HOUR);
        assert!((acct.s3_gb_hours - 2.0).abs() < 0.01);
        let report = acct.cost_report(HOUR);
        assert!(report.s3_usd > 0.0);
        assert!(report.sqs_usd > 0.0);
        assert_eq!(report.ec2_usd, 0.0);
    }

    #[test]
    fn deterministic_under_seed() {
        let p1 = AwsAccount::new(7, Volatility::High)
            .ec2
            .market
            .price_at("m5.large", 5 * HOUR);
        let p2 = AwsAccount::new(7, Volatility::High)
            .ec2
            .market
            .price_at("m5.large", 5 * HOUR);
        assert_eq!(p1, p2);
    }
}
