//! # ds-rs — Distributed-Something, reproduced as a Rust + XLA stack
//!
//! A reproduction of Weisbart & Cimini, *"Distributed-Something: scripts to
//! leverage AWS storage and computing for distributed workflows at scale"*
//! (2022).  The paper's system coordinates five AWS services — S3, SQS,
//! EC2 Spot Fleet, ECS, and CloudWatch — so that any containerized
//! workload can be fanned out over cheap preemptible machines with four
//! single-line commands (`setup`, `submitJob`, `startCluster`, `monitor`).
//!
//! Here the AWS control plane is a faithful discrete-event simulation
//! ([`aws`], driven by [`sim`]), the "Dockerized workload" is an
//! AOT-compiled XLA executable run via PJRT ([`runtime`], [`workloads`]),
//! and the paper's four commands are [`coordinator`].  Storage is not
//! free: jobs that declare byte sizes move them through a
//! bandwidth-aware S3 data plane ([`aws::s3::dataplane`]) that shares
//! instance NICs and bucket throughput max-min fairly.  Capacity is not
//! hand-tuned: CloudWatch alarms on the SQS backlog drive typed
//! target-tracking and step scaling policies that grow and shrink the
//! fleet mid-run ([`coordinator::autoscale`]).  Whole
//! configuration matrices replay in parallel through the scenario-sweep
//! engine ([`coordinator::sweep`]) with cross-seed aggregation in
//! [`metrics`]; the sweep surface itself — CLI flags, the declarative
//! Sweep file, the plan builder, labels, and the report's axis keys —
//! is generated from one typed axis registry ([`scenario`]).  See
//! DESIGN.md for the substitution table, experiment index, sweep-engine
//! design, and the data-plane flow model (§7).

pub mod aws;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod json;
pub mod metrics;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod testutil;
pub mod topology;
pub mod traffic;
pub mod worker;
pub mod workflow;
pub mod workloads;
