//! Sharded sweep execution gates (DESIGN.md §10).
//!
//! The contract under test: `run_sweep_sharded` is *indistinguishable*
//! from single-process `run_sweep` — same report struct, same JSON
//! bytes, same table bytes — for any shard count, any per-shard thread
//! count, and any completion order; and worker failure is never silent:
//! every injected fault (kill, garbage, truncation, hang, version skew,
//! dropped/duplicated cells) ends in either a recovered retry or a
//! typed `ShardError`, never a merged report with a hole in the matrix.
//!
//! Three plan shapes mirror the determinism matrix: a serial sweep, a
//! crashy data-shaped sweep (crash MTTF in `base_opts` — exercising the
//! non-axis options on the wire), and a scaling × data sweep.  The fast
//! differential tests run every shard through [`InProcExecutor`] (same
//! code path as a child minus the OS process); the `real process`
//! section spawns genuine `ds shard-worker` children via
//! `CARGO_BIN_EXE_ds`, including workers that really die, hang, and
//! print garbage (armed through `DS_SHARD_FAULT*` in the child's
//! environment only).

use std::time::Duration;

use ds_rs::aws::ec2::Volatility;
use ds_rs::aws::s3::dataplane::NetProfile;
use ds_rs::coordinator::autoscale::ScalingMode;
use ds_rs::coordinator::shard::{
    report_from_wire, report_to_wire, run_sweep_sharded, shard_plan, ExecFailure, InProcExecutor,
    ProcessExecutor, ShardError, ShardExecutor, ShardOptions, SweepShardRequest, WIRE_VERSION,
};
use ds_rs::coordinator::sweep::{run_sweep, ScenarioMatrix, SweepPlan, SweepRun};
use ds_rs::json::Value;
use ds_rs::metrics::{RunReport, ScenarioSummary, SweepReport};
use ds_rs::sim::MINUTE;
use ds_rs::testutil::fixtures::{plate_jobs, quick_cfg};
use ds_rs::testutil::shard_exec::{Fault, FaultyExecutor};
use ds_rs::testutil::{forall_r, forall};
use ds_rs::workloads::DurationModel;

// ---------------------------------------------------------------------
// The determinism-matrix plans
// ---------------------------------------------------------------------

/// 2 machines-axis scenarios × 4 seeds = 8 cells, no failure modes.
fn serial_plan() -> SweepPlan {
    let matrix = ScenarioMatrix {
        seeds: (0..4).collect(),
        cluster_machines: vec![2, 4],
        models: vec![DurationModel {
            mean_s: 40.0,
            cv: 0.3,
            ..Default::default()
        }],
        ..Default::default()
    };
    SweepPlan::new(quick_cfg(3), plate_jobs(6, 2), matrix)
}

/// 1 scenario × 2 seeds = 2 cells: high volatility, data-shaped jobs on
/// a narrow network, stall/fail probabilities, and — crucially for the
/// wire contract — a crash MTTF set in `base_opts`, which no axis
/// overlays, so it only survives sharding if the envelope carries it.
fn crashy_data_plan() -> SweepPlan {
    let matrix = ScenarioMatrix {
        seeds: vec![7, 13],
        cluster_machines: vec![3],
        volatilities: vec![Volatility::High],
        input_mbs: vec![24.0],
        net_profiles: vec![NetProfile::narrow()],
        models: vec![DurationModel {
            mean_s: 45.0,
            cv: 0.3,
            stall_prob: 0.02,
            fail_prob: 0.05,
        }],
        ..Default::default()
    };
    let mut plan = SweepPlan::new(quick_cfg(3), plate_jobs(6, 2), matrix);
    plan.base_opts.crash_mttf = Some(40 * MINUTE);
    plan
}

/// 2 DAG shapes × 2 sharing modes × 2 seeds = 8 cells: the workflow
/// axes on the wire.  The embedded plan matrix must carry whole inline
/// DAGs to the workers (a shard worker never chases shape names or file
/// paths), and the readiness scheduler's mid-run release sends must
/// stay bit-stable across process boundaries.
fn workflow_plan() -> SweepPlan {
    use ds_rs::workflow::SharingMode;
    use ds_rs::workloads::dag;
    SweepPlan::builder()
        .config(quick_cfg(3))
        // Workflow cells ignore the Job file: the DAG is the workload.
        .jobs(plate_jobs(2, 1))
        .seeds([7, 8])
        .workflows([Some(dag::diamond()), Some(dag::mosaic())])
        .sharings([SharingMode::S3Staging, SharingMode::NodeLocal])
        .models([DurationModel {
            mean_s: 45.0,
            cv: 0.3,
            ..Default::default()
        }])
        .build()
        .expect("workflow plan")
}

/// 6 scenarios (3 scaling modes × 2 input shapes) × 2 seeds = 12 cells.
fn scaling_data_plan() -> SweepPlan {
    let matrix = ScenarioMatrix {
        seeds: vec![0, 1],
        cluster_machines: vec![3],
        scalings: ScalingMode::ALL.to_vec(),
        scaling_targets: vec![8.0],
        input_mbs: vec![0.0, 24.0],
        models: vec![DurationModel {
            mean_s: 120.0,
            cv: 0.3,
            ..Default::default()
        }],
        ..Default::default()
    };
    SweepPlan::new(quick_cfg(3), plate_jobs(5, 2), matrix)
}

/// 4 scenarios (2 topologies × 2 placements) × 2 seeds = 8 cells; the
/// faulted topology travels inline through the rendered Sweep file, so
/// the differential covers the TOPOLOGY axis codec end to end.
fn topology_plan() -> SweepPlan {
    use ds_rs::topology::{ClusterTopology, FaultKind, Placement};
    let faulted = ClusterTopology::builder("two-region")
        .domain("us-east-1a", "us-east-1")
        .domain("us-west-2a", "us-west-2")
        .fault(FaultKind::AzOutage, "us-east-1a", 10, 60, 1.0)
        .build()
        .expect("faulted topology");
    SweepPlan::builder()
        .config(quick_cfg(3))
        .jobs(plate_jobs(5, 2).with_uniform_data(8_000_000, 1_000_000))
        .seeds([7, 8])
        .topologies([ClusterTopology::shape("three-az"), Some(faulted)])
        .placements([Placement::Pack, Placement::Spread])
        .models([DurationModel {
            mean_s: 45.0,
            cv: 0.3,
            ..Default::default()
        }])
        .build()
        .expect("topology plan")
}

/// 4 scenarios (2 traffic shapes × 2 queueing policies) × 2 seeds =
/// 8 cells; the custom spec travels inline through the rendered Sweep
/// file, so the differential covers the TRAFFIC axis codec end to end.
fn traffic_plan() -> SweepPlan {
    use ds_rs::traffic::{QueueingPolicy, TrafficSpec};
    let bursty = TrafficSpec::builder("bursty")
        .tenant("victim", 10, 1, 1, 300)
        .tenant("noisy", 40, 1, 0, 3600)
        .poisson("victim", 1.0)
        .heavy_tailed("noisy", 1.5, 0.1)
        .build()
        .expect("bursty traffic");
    SweepPlan::builder()
        .config(quick_cfg(3))
        // Traffic cells ignore the Job file: the generators are the
        // workload.
        .jobs(plate_jobs(2, 1))
        .seeds([7, 8])
        .traffics([TrafficSpec::shape("two-tenant"), Some(bursty)])
        .queueings([QueueingPolicy::Fifo, QueueingPolicy::FairShare])
        .models([DurationModel {
            mean_s: 45.0,
            cv: 0.3,
            ..Default::default()
        }])
        .build()
        .expect("traffic plan")
}

/// Full-fidelity equality: struct, per-cell results, JSON bytes, table
/// bytes.
fn assert_runs_identical(reference: &SweepRun, sharded: &SweepRun, label: &str) {
    assert_eq!(reference.cells, sharded.cells, "{label}: cells diverge");
    assert_eq!(reference.report, sharded.report, "{label}: report diverges");
    assert_eq!(
        reference.report.to_json().pretty(),
        sharded.report.to_json().pretty(),
        "{label}: JSON bytes diverge"
    );
    assert_eq!(
        reference.report.table().render(),
        sharded.report.table().render(),
        "{label}: table bytes diverge"
    );
}

fn sharded_inproc(plan: &SweepPlan, shards: usize, threads: usize) -> SweepRun {
    let opts = ShardOptions {
        shards,
        threads,
        retries: 0,
    };
    run_sweep_sharded(plan, &opts, &InProcExecutor).unwrap()
}

// ---------------------------------------------------------------------
// Differential gates (in-process executor)
// ---------------------------------------------------------------------

#[test]
fn sharded_serial_sweep_identical_across_shard_and_thread_matrix() {
    let plan = serial_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    for shards in [1, 2, 8] {
        for threads in [1, 2, 8] {
            let sharded = sharded_inproc(&plan, shards, threads);
            assert_runs_identical(
                &reference,
                &sharded,
                &format!("serial {shards} shards x {threads} threads"),
            );
        }
    }
}

#[test]
fn sharded_crashy_data_sweep_identical_at_1_2_and_8_shards() {
    let plan = crashy_data_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    // Sanity: the plan actually exercises the data plane and crashes —
    // otherwise this differential is weaker than it claims.
    assert!(reference.cells.iter().any(|c| c.report.data.total_bytes() > 0));
    assert!(reference.cells.iter().any(|c| c.report.stats.crashes > 0));
    for shards in [1, 2, 8] {
        let sharded = sharded_inproc(&plan, shards, 2);
        assert_runs_identical(&reference, &sharded, &format!("crashy {shards} shards"));
    }
}

#[test]
fn sharded_scaling_data_sweep_identical_at_1_2_and_8_shards() {
    let plan = scaling_data_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    assert!(reference
        .report
        .scenarios
        .iter()
        .any(|s| s.scaling.policy == "target-tracking"));
    for shards in [1, 2, 8] {
        let sharded = sharded_inproc(&plan, shards, 2);
        assert_runs_identical(&reference, &sharded, &format!("scaling {shards} shards"));
    }
}

#[test]
fn sharded_workflow_sweep_identical_at_1_3_and_8_shards() {
    let plan = workflow_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    // Sanity: every cell really ran a DAG with mid-run releases and
    // staged artifacts (the differential is vacuous otherwise).
    assert!(reference.cells.iter().all(|c| c.report.workflow.releases > 0));
    assert!(reference
        .cells
        .iter()
        .any(|c| c.report.workflow.artifact_bytes_staged > 0));
    for shards in [1, 3, 8] {
        let sharded = sharded_inproc(&plan, shards, 2);
        assert_runs_identical(&reference, &sharded, &format!("workflow {shards} shards"));
    }
}

#[test]
fn sharded_topology_sweep_identical_at_1_3_and_8_shards() {
    let plan = topology_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    // Sanity: every cell really ran multi-domain (the differential is
    // vacuous otherwise), and the faulted cells observed their outage.
    assert!(reference
        .cells
        .iter()
        .all(|c| !c.report.topology.domains.is_empty()));
    assert!(reference
        .cells
        .iter()
        .any(|c| !c.report.topology.outages.is_empty()));
    for shards in [1, 3, 8] {
        let sharded = sharded_inproc(&plan, shards, 2);
        assert_runs_identical(&reference, &sharded, &format!("topology {shards} shards"));
    }
}

#[test]
fn sharded_traffic_sweep_identical_at_1_3_and_8_shards() {
    let plan = traffic_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    // Sanity: every cell really ran multi-tenant (the differential is
    // vacuous otherwise) and completed both tenants' jobs.
    for c in &reference.cells {
        assert_eq!(c.report.traffic.tenants.len(), 2);
        let done: u64 = c.report.traffic.tenants.iter().map(|t| t.completed).sum();
        assert_eq!(done, c.report.stats.completed);
    }
    for shards in [1, 3, 8] {
        let sharded = sharded_inproc(&plan, shards, 2);
        assert_runs_identical(&reference, &sharded, &format!("traffic {shards} shards"));
    }
}

#[test]
fn traffic_shards_survive_kill_and_retry_with_identical_bytes() {
    let plan = traffic_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    let exec = FaultyExecutor::new(InProcExecutor).fault(1, 0, Fault::Kill);
    let opts = ShardOptions {
        shards: 3,
        threads: 2,
        retries: 1,
    };
    let run = run_sweep_sharded(&plan, &opts, &exec).unwrap();
    assert_runs_identical(&reference, &run, "traffic kill then retry");
    assert_eq!(exec.attempts(1), 2, "shard 1 should retry once");
    assert_eq!(exec.attempts(0), 1, "shard 0 was healthy");
    assert_eq!(exec.attempts(2), 1, "shard 2 was healthy");
}

#[test]
fn traffic_request_round_trip_preserves_inline_specs() {
    // Like the workflow and topology axes, TRAFFIC values are whole
    // JSON objects in the Sweep file; the envelope must round-trip them
    // without flattening.
    let plan = traffic_plan();
    let req = SweepShardRequest {
        plan: plan.clone(),
        threads: 2,
        assignment: shard_plan(8, 3)[0].clone(),
    };
    let decoded =
        SweepShardRequest::from_json(&ds_rs::json::parse(&req.to_json().pretty()).unwrap())
            .unwrap();
    assert_eq!(decoded.plan.matrix.traffics, plan.matrix.traffics);
    assert_eq!(decoded.plan.matrix.queueings, plan.matrix.queueings);
    let a = run_sweep(&plan, 2).unwrap();
    let b = run_sweep(&decoded.plan, 2).unwrap();
    assert_runs_identical(&a, &b, "traffic request round trip");
}

#[test]
fn workflow_shards_survive_kill_and_retry_with_identical_bytes() {
    let plan = workflow_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    let exec = FaultyExecutor::new(InProcExecutor).fault(1, 0, Fault::Kill);
    let opts = ShardOptions {
        shards: 3,
        threads: 2,
        retries: 1,
    };
    let run = run_sweep_sharded(&plan, &opts, &exec).unwrap();
    assert_runs_identical(&reference, &run, "workflow kill then retry");
    assert_eq!(exec.attempts(1), 2, "shard 1 should retry once");
    assert_eq!(exec.attempts(0), 1, "shard 0 was healthy");
    assert_eq!(exec.attempts(2), 1, "shard 2 was healthy");
}

#[test]
fn workflow_request_round_trip_preserves_inline_dags() {
    // The workflow axis is the first whose file values are whole JSON
    // objects; the envelope must round-trip them without flattening.
    let plan = workflow_plan();
    let req = SweepShardRequest {
        plan: plan.clone(),
        threads: 2,
        assignment: shard_plan(8, 3)[0].clone(),
    };
    let decoded =
        SweepShardRequest::from_json(&ds_rs::json::parse(&req.to_json().pretty()).unwrap())
            .unwrap();
    assert_eq!(decoded.plan.matrix.workflows.len(), 2);
    for (a, b) in decoded
        .plan
        .matrix
        .workflows
        .iter()
        .zip(&plan.matrix.workflows)
    {
        assert_eq!(
            a.as_ref().unwrap().fingerprint(),
            b.as_ref().unwrap().fingerprint()
        );
    }
    let a = run_sweep(&plan, 2).unwrap();
    let b = run_sweep(&decoded.plan, 2).unwrap();
    assert_runs_identical(&a, &b, "workflow request round trip");
}

// ---------------------------------------------------------------------
// Shard-plan properties
// ---------------------------------------------------------------------

#[test]
fn shard_plan_covers_every_cell_exactly_once_balanced_within_one() {
    forall_r(
        "shard-plan-partition",
        200,
        0xDEC0DE,
        |r| (1 + r.below(200) as usize, 1 + r.below(24) as usize),
        |&(cells, shards)| {
            let plans = shard_plan(cells, shards);
            let mut seen: Vec<usize> =
                plans.iter().flat_map(|p| p.cells.iter().copied()).collect();
            seen.sort_unstable();
            if seen != (0..cells).collect::<Vec<_>>() {
                return Err(format!("not a partition: {seen:?}"));
            }
            let sizes: Vec<usize> = plans.iter().map(|p| p.cells.len()).collect();
            let (min, max) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            if max - min > 1 {
                return Err(format!("unbalanced: sizes {sizes:?}"));
            }
            for (i, p) in plans.iter().enumerate() {
                if p.index != i || p.count != plans.len() {
                    return Err(format!("bad labels on shard {i}: {p:?}"));
                }
                if p.cells.windows(2).any(|w| w[0] >= w[1]) {
                    return Err(format!("cells not ascending on shard {i}: {p:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shard_plan_is_stable_under_reinvocation() {
    forall(
        "shard-plan-stability",
        100,
        0x5EED,
        |r| (1 + r.below(500) as usize, 1 + r.below(16) as usize),
        |&(cells, shards)| shard_plan(cells, shards) == shard_plan(cells, shards),
    );
}

// ---------------------------------------------------------------------
// Merge-fold properties (satellite: from_reports associativity)
// ---------------------------------------------------------------------

/// Overwrite every f64 in the report with small dyadic rationals
/// (multiples of 0.25): their sums are exact in f64 regardless of
/// addition order, which is what lets the raw `from_reports` fold be
/// asserted permutation-invariant without the canonical pre-sort.
fn dyadicize(report: &mut RunReport, i: u64) {
    let d = |k: u64| (i * 16 + k) as f64 * 0.25;
    report.cost.ec2_usd = d(1);
    report.cost.sqs_usd = d(2);
    report.cost.s3_usd = d(3);
    report.cost.s3_egress_usd = d(4);
    report.cost.cloudwatch_usd = d(5);
    report.cost.machine_hours = d(6);
    report.cost.on_demand_equivalent_usd = d(7);
    report.data.request_usd = d(8);
    report.data.egress_usd = d(9);
    report.scaling.capacity_unit_hours = d(10);
    for (k, pool) in report.pools.iter_mut().enumerate() {
        pool.machine_hours = d(11 + 2 * k as u64);
        pool.cost_usd = d(12 + 2 * k as u64);
    }
}

#[test]
fn from_reports_shard_arrival_order_folds_identically_to_sorted_order() {
    // Real reports (so pools/data/scaling are populated), dyadic f64s
    // (so the sums cannot depend on fold order).
    let run = run_sweep(&serial_plan(), 2).unwrap();
    let mut reports: Vec<RunReport> = run.cells[0..4].iter().map(|c| c.report.clone()).collect();
    for (i, r) in reports.iter_mut().enumerate() {
        dyadicize(r, i as u64);
    }
    let sorted: Vec<&RunReport> = reports.iter().collect();
    let sorted_json = ScenarioSummary::from_reports("perm", &sorted).to_json().pretty();
    // Every arrival order a 4-shard sweep could deliver this scenario in.
    let orders: &[[usize; 4]] = &[
        [3, 1, 0, 2],
        [1, 0, 3, 2],
        [2, 3, 1, 0],
        [3, 2, 1, 0],
    ];
    for order in orders {
        let arrival: Vec<&RunReport> = order.iter().map(|&k| &reports[k]).collect();
        let arrival_json = ScenarioSummary::from_reports("perm", &arrival).to_json().pretty();
        assert_eq!(arrival_json, sorted_json, "order {order:?}");
    }
}

#[test]
fn from_cells_merges_shard_results_identically_to_the_engine() {
    // The real thing `run_sweep_sharded` relies on: feeding the cells
    // to `SweepReport::from_cells` in any shard arrival order produces
    // the single-process report, bit for bit — including its JSON.
    let plan = scaling_data_plan();
    let run = run_sweep(&plan, 2).unwrap();
    let nseeds = plan.matrix.seeds.len();
    let ids: Vec<(String, Value)> = run
        .scenarios
        .iter()
        .map(|sc| (sc.label(), sc.axis_json()))
        .collect();
    let indexed: Vec<(usize, usize, &RunReport)> = run
        .cells
        .iter()
        .enumerate()
        .map(|(i, c)| (c.scenario, i % nseeds, &c.report))
        .collect();
    // Interleave as a 3-shard round-robin completion would: shard 2
    // first, then 0, then 1.
    for first in 0..3 {
        let mut arrival: Vec<(usize, usize, &RunReport)> = Vec::new();
        for s in [first, (first + 1) % 3, (first + 2) % 3] {
            arrival.extend(indexed.iter().skip(s).step_by(3).copied());
        }
        let merged = SweepReport::from_cells(&ids, &arrival);
        assert_eq!(merged, run.report);
        assert_eq!(merged.to_json().pretty(), run.report.to_json().pretty());
    }
}

// ---------------------------------------------------------------------
// Wire codec round-trips
// ---------------------------------------------------------------------

#[test]
fn report_wire_codec_round_trips_real_cells_bit_exactly() {
    // Crashy + scaling cells cover every report field family: stats,
    // nullable drain time, pools, data plane, scaling timeline.
    for plan in [crashy_data_plan(), scaling_data_plan()] {
        let run = run_sweep(&plan, 2).unwrap();
        for cell in &run.cells {
            let wire = report_to_wire(&cell.report).pretty();
            let parsed = ds_rs::json::parse(&wire).unwrap();
            let back = report_from_wire(&parsed).unwrap();
            assert_eq!(back, cell.report);
            // And the re-encoded bytes are stable (canonical encoding).
            assert_eq!(report_to_wire(&back).pretty(), wire);
        }
    }
}

#[test]
fn shard_request_round_trip_preserves_the_whole_plan() {
    // The crashy plan is the adversarial one: crash MTTF lives in
    // base_opts (not the Sweep file), so this round trip proves the
    // envelope's base_opts channel actually works.
    let plan = crashy_data_plan();
    let req = SweepShardRequest {
        plan: plan.clone(),
        threads: 2,
        assignment: shard_plan(2, 2)[0].clone(),
    };
    let decoded = SweepShardRequest::from_json(&ds_rs::json::parse(&req.to_json().pretty()).unwrap())
        .unwrap();
    assert_eq!(decoded.plan.base_opts.crash_mttf, Some(40 * MINUTE));
    let a = run_sweep(&plan, 2).unwrap();
    let b = run_sweep(&decoded.plan, 2).unwrap();
    assert_runs_identical(&a, &b, "request round trip");
}

// ---------------------------------------------------------------------
// Fault injection (scripted executor double)
// ---------------------------------------------------------------------

fn fault_opts() -> ShardOptions {
    ShardOptions {
        shards: 2,
        threads: 2,
        retries: 1,
    }
}

#[test]
fn every_fault_kind_recovers_on_retry_with_identical_bytes() {
    let plan = crashy_data_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    for fault in [
        Fault::Kill,
        Fault::Garbage,
        Fault::Truncate,
        Fault::Hang,
        Fault::VersionBump,
    ] {
        let exec = FaultyExecutor::new(InProcExecutor).fault(0, 0, fault);
        let run = run_sweep_sharded(&plan, &fault_opts(), &exec).unwrap();
        assert_runs_identical(&reference, &run, &format!("{fault:?} then retry"));
        assert_eq!(exec.attempts(0), 2, "{fault:?}: shard 0 should retry once");
        assert_eq!(exec.attempts(1), 1, "{fault:?}: shard 1 was healthy");
    }
}

#[test]
fn exhausted_retries_fail_typed_with_the_childs_stderr_attached() {
    let plan = crashy_data_plan();
    let exec = FaultyExecutor::new(InProcExecutor)
        .fault(1, 0, Fault::Kill)
        .fault(1, 1, Fault::Kill)
        .fault(1, 2, Fault::Kill);
    let opts = ShardOptions {
        shards: 2,
        threads: 2,
        retries: 2,
    };
    let err = run_sweep_sharded(&plan, &opts, &exec).unwrap_err();
    let shard_err = err
        .downcast_ref::<ShardError>()
        .unwrap_or_else(|| panic!("untyped error: {err:#}"));
    match shard_err {
        ShardError::Exhausted {
            shard: 1,
            attempts: 3,
            last,
        } => match last.as_ref() {
            ShardError::Exec {
                shard: 1,
                failure: ExecFailure::Crashed { stderr, .. },
            } => assert!(
                stderr.contains("killed mid-shard"),
                "stderr not surfaced: {stderr:?}"
            ),
            other => panic!("wrong last error: {other:?}"),
        },
        other => panic!("wrong error shape: {other:?}"),
    }
    assert_eq!(exec.attempts(1), 3);
}

#[test]
fn persistent_version_skew_is_a_typed_version_mismatch() {
    let plan = crashy_data_plan();
    let exec = FaultyExecutor::new(InProcExecutor)
        .fault(0, 0, Fault::VersionBump)
        .fault(0, 1, Fault::VersionBump)
        .fault(0, 2, Fault::VersionBump);
    let opts = ShardOptions {
        shards: 2,
        threads: 1,
        retries: 2,
    };
    let err = run_sweep_sharded(&plan, &opts, &exec).unwrap_err();
    match err.downcast_ref::<ShardError>() {
        Some(ShardError::Exhausted { last, .. }) => match last.as_ref() {
            ShardError::VersionMismatch { shard: 0, got, want } => {
                assert_eq!(*got, WIRE_VERSION + 1);
                assert_eq!(*want, WIRE_VERSION);
            }
            other => panic!("wrong last error: {other:?}"),
        },
        other => panic!("wrong error shape: {other:?}"),
    }
}

/// Executor that tampers with a healthy worker's result: drops the last
/// cell, or duplicates the first.  Both must die in assignment
/// validation — the merge must never see them.
struct TamperingExecutor {
    drop_last: bool,
}

impl ShardExecutor for TamperingExecutor {
    fn run_shard(&self, request_json: &str) -> Result<String, ExecFailure> {
        let out = InProcExecutor.run_shard(request_json)?;
        let v = ds_rs::json::parse(&out).expect("worker emits JSON");
        let tampered = match v {
            Value::Obj(fields) => Value::Obj(
                fields
                    .into_iter()
                    .map(|(k, val)| {
                        if k != "cells" {
                            return (k, val);
                        }
                        let Value::Arr(mut cells) = val else {
                            return (k, val);
                        };
                        if self.drop_last {
                            cells.pop();
                        } else if let Some(first) = cells.first().cloned() {
                            cells.push(first);
                        }
                        (k, Value::Arr(cells))
                    })
                    .collect(),
            ),
            other => other,
        };
        Ok(tampered.pretty())
    }
}

#[test]
fn dropped_and_duplicated_cells_are_typed_assignment_mismatches() {
    let plan = serial_plan();
    for drop_last in [true, false] {
        let exec = TamperingExecutor { drop_last };
        let opts = ShardOptions {
            shards: 2,
            threads: 2,
            retries: 0,
        };
        let err = run_sweep_sharded(&plan, &opts, &exec).unwrap_err();
        match err.downcast_ref::<ShardError>() {
            Some(ShardError::Exhausted { last, .. }) => {
                assert!(
                    matches!(last.as_ref(), ShardError::AssignmentMismatch { .. }),
                    "drop_last={drop_last}: wrong last error: {last:?}"
                );
            }
            other => panic!("drop_last={drop_last}: wrong error shape: {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------
// Real worker processes (`ds shard-worker` via CARGO_BIN_EXE_ds)
// ---------------------------------------------------------------------

fn process_exec() -> ProcessExecutor {
    ProcessExecutor::new(env!("CARGO_BIN_EXE_ds"), Duration::from_secs(120))
}

/// A scratch marker path unique to this test binary invocation; the
/// `DS_SHARD_FAULT_ONCE` hook creates it when the fault trips.
fn marker(name: &str) -> std::path::PathBuf {
    let path = std::env::temp_dir().join(format!("ds-shard-{name}-{}", std::process::id()));
    std::fs::remove_file(&path).ok();
    path
}

#[test]
fn real_process_shards_match_single_process_bytes() {
    let plan = crashy_data_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    let opts = ShardOptions {
        shards: 2,
        threads: 1,
        retries: 0,
    };
    let run = run_sweep_sharded(&plan, &opts, &process_exec()).unwrap();
    assert_runs_identical(&reference, &run, "real process, 2 shards");
}

#[test]
fn real_worker_killed_once_recovers_on_the_fresh_process() {
    let plan = crashy_data_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    let marker = marker("kill-once");
    let mut exec = process_exec();
    exec.envs = vec![
        ("DS_SHARD_FAULT".into(), "kill".into()),
        ("DS_SHARD_FAULT_SHARD".into(), "0".into()),
        ("DS_SHARD_FAULT_ONCE".into(), marker.display().to_string()),
    ];
    let opts = ShardOptions {
        shards: 2,
        threads: 1,
        retries: 1,
    };
    let run = run_sweep_sharded(&plan, &opts, &exec).unwrap();
    assert!(marker.exists(), "the fault never tripped — test is vacuous");
    std::fs::remove_file(&marker).ok();
    assert_runs_identical(&reference, &run, "killed once, retried");
}

#[test]
fn real_worker_garbage_once_recovers_on_the_fresh_process() {
    let plan = crashy_data_plan();
    let reference = run_sweep(&plan, 2).unwrap();
    let marker = marker("garbage-once");
    let mut exec = process_exec();
    exec.envs = vec![
        ("DS_SHARD_FAULT".into(), "garbage".into()),
        ("DS_SHARD_FAULT_SHARD".into(), "1".into()),
        ("DS_SHARD_FAULT_ONCE".into(), marker.display().to_string()),
    ];
    let opts = ShardOptions {
        shards: 2,
        threads: 1,
        retries: 1,
    };
    let run = run_sweep_sharded(&plan, &opts, &exec).unwrap();
    assert!(marker.exists(), "the fault never tripped — test is vacuous");
    std::fs::remove_file(&marker).ok();
    assert_runs_identical(&reference, &run, "garbage once, retried");
}

#[test]
fn real_worker_hang_times_out_as_a_typed_error() {
    let plan = crashy_data_plan();
    let mut exec = ProcessExecutor::new(env!("CARGO_BIN_EXE_ds"), Duration::from_millis(400));
    exec.envs = vec![("DS_SHARD_FAULT".into(), "hang".into())];
    let opts = ShardOptions {
        shards: 1,
        threads: 1,
        retries: 0,
    };
    let err = run_sweep_sharded(&plan, &opts, &exec).unwrap_err();
    match err.downcast_ref::<ShardError>() {
        Some(ShardError::Exhausted { attempts: 1, last, .. }) => {
            assert!(
                matches!(
                    last.as_ref(),
                    ShardError::Exec {
                        failure: ExecFailure::Timeout(_),
                        ..
                    }
                ),
                "wrong last error: {last:?}"
            );
        }
        other => panic!("wrong error shape: {other:?}"),
    }
}

/// The full differential matrix against real worker processes.  Heavy
/// (dozens of child processes), so the default lane skips it; the
/// release CI shard lane runs it with `--include-ignored`.
#[test]
#[ignore = "real-process differential matrix; the release CI shard lane runs it with --ignored"]
fn real_process_differential_matrix() {
    for (name, plan) in [
        ("serial", serial_plan()),
        ("crashy", crashy_data_plan()),
        ("scaling", scaling_data_plan()),
        ("workflow", workflow_plan()),
        ("traffic", traffic_plan()),
    ] {
        let reference = run_sweep(&plan, 2).unwrap();
        for shards in [2, 8] {
            for threads in [2, 8] {
                let opts = ShardOptions {
                    shards,
                    threads,
                    retries: 0,
                };
                let run = run_sweep_sharded(&plan, &opts, &process_exec()).unwrap();
                assert_runs_identical(
                    &reference,
                    &run,
                    &format!("{name}: real {shards} shards x {threads} threads"),
                );
            }
        }
    }
}
