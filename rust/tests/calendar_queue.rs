//! Property suite for the calendar event queue (DESIGN.md §"Event core").
//!
//! The calendar backend must be *bit-equivalent* to the reference binary
//! heap, not merely correct: on any workload the pops come out in
//! identical `(time, seq)` order.  These tests drive randomized schedules
//! through both implementations and compare full traces, alongside direct
//! invariant checks: globally time-ordered pops, FIFO on equal
//! timestamps, and `len`/`peek_time`/`is_empty` accounting at every step.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use ds_rs::sim::calendar::CalendarQueue;
use ds_rs::sim::{EventQueue, QueueKind, SimRng};
use ds_rs::testutil::forall_r;

/// One step of a randomized queue workload.
#[derive(Debug, Clone, Copy)]
enum Op {
    /// Schedule an event `delay` ms after the current clock.
    Push { delay: u64 },
    /// Pop the minimum (no-op on an empty queue).
    Pop,
}

/// A DES-shaped random script: push-heavy, with a large tie mass
/// (delay 0), mid-range delays, and rare far-future jumps that force the
/// calendar's direct-search fallback and its resize paths.
fn random_script(rng: &mut SimRng) -> Vec<Op> {
    let n = 40 + rng.below(160);
    (0..n)
        .map(|_| {
            if rng.chance(0.6) {
                Op::Push {
                    delay: match rng.below(10) {
                        0..=3 => 0,
                        4..=7 => rng.below(5_000),
                        8 => rng.below(200_000),
                        _ => rng.below(50_000_000),
                    },
                }
            } else {
                Op::Pop
            }
        })
        .collect()
}

/// Replay a script on an [`EventQueue`] backend, returning the pop trace.
/// Payloads number the pushes, so a trace pins both times and identities.
fn replay(kind: QueueKind, script: &[Op]) -> Vec<(u64, u32)> {
    let mut q = EventQueue::with_kind(kind);
    let mut payload = 0u32;
    let mut trace = Vec::new();
    for op in script {
        match *op {
            Op::Push { delay } => {
                payload += 1;
                q.schedule_in(delay, payload);
            }
            Op::Pop => {
                if let Some((t, e)) = q.pop() {
                    trace.push((t, e));
                }
            }
        }
    }
    while let Some((t, e)) = q.pop() {
        trace.push((t, e));
    }
    trace
}

/// Raw differential: the [`CalendarQueue`] against a shadow
/// `BinaryHeap` on the same `(time, seq)` keys, with `len`, `is_empty`,
/// and `peek_time` checked after every operation and a full drain at the
/// end.
#[test]
fn calendar_matches_binary_heap_step_by_step() {
    forall_r(
        "calendar-vs-heap-raw",
        80,
        0xCA1,
        random_script,
        |script| {
            let mut cal: CalendarQueue<u64> = CalendarQueue::new();
            let mut heap: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
            let mut now = 0u64;
            let mut seq = 0u64;
            for op in script {
                match *op {
                    Op::Push { delay } => {
                        seq += 1;
                        let t = now + delay;
                        cal.push(t, seq, seq);
                        heap.push(Reverse((t, seq)));
                    }
                    Op::Pop => {
                        let expect = heap.pop().map(|Reverse((t, s))| (t, s, s));
                        let got = cal.pop();
                        if got != expect {
                            return Err(format!(
                                "pop mismatch: calendar {got:?} vs heap {expect:?}"
                            ));
                        }
                        if let Some((t, _, _)) = got {
                            now = t;
                        }
                    }
                }
                if cal.len() != heap.len() {
                    return Err(format!("len mismatch: {} vs {}", cal.len(), heap.len()));
                }
                if cal.is_empty() != heap.is_empty() {
                    return Err("is_empty mismatch".into());
                }
                let peek = heap.peek().map(|&Reverse((t, _))| t);
                if cal.peek_time() != peek {
                    return Err(format!(
                        "peek mismatch: {:?} vs {:?}",
                        cal.peek_time(),
                        peek
                    ));
                }
            }
            loop {
                let expect = heap.pop().map(|Reverse((t, s))| (t, s, s));
                let got = cal.pop();
                if got != expect {
                    return Err(format!("drain mismatch: {got:?} vs {expect:?}"));
                }
                if got.is_none() {
                    return Ok(());
                }
            }
        },
    );
}

/// End-to-end differential through the public [`EventQueue`] API: the two
/// backends produce identical traces, and every trace is globally ordered
/// by time with FIFO tie-breaking (payloads are assigned in schedule
/// order, so within one timestamp they must ascend).
#[test]
fn event_queue_backends_produce_identical_traces() {
    forall_r(
        "heap-vs-calendar-traces",
        80,
        0xE0E,
        random_script,
        |script| {
            let heap = replay(QueueKind::Heap, script);
            let cal = replay(QueueKind::Calendar, script);
            if heap != cal {
                return Err(format!(
                    "traces diverge: heap {} pops, calendar {} pops",
                    heap.len(),
                    cal.len()
                ));
            }
            for w in cal.windows(2) {
                let ((t0, p0), (t1, p1)) = (w[0], w[1]);
                if t1 < t0 || (t1 == t0 && p1 < p0) {
                    return Err(format!(
                        "order violated: ({t0},{p0}) then ({t1},{p1})"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Interleaved equal-timestamp bursts big enough to cross several resize
/// thresholds pop in exact insertion order on both backends.
#[test]
fn equal_timestamp_bursts_pop_in_insertion_order() {
    forall_r(
        "fifo-equal-timestamps",
        40,
        0xF1F0,
        |rng| {
            // Three distinct instants; pushes round-robin across them so
            // the schedule order interleaves timestamps.
            let times: Vec<u64> = (0..3).map(|b| b * 10_000 + rng.below(1_000)).collect();
            let rounds = 15 + rng.below(40);
            let mut pushes = Vec::new();
            for _ in 0..rounds {
                pushes.extend_from_slice(&times);
            }
            pushes
        },
        |pushes| {
            let mut expected: Vec<(u64, usize)> =
                pushes.iter().enumerate().map(|(i, &t)| (t, i)).collect();
            expected.sort_by_key(|&(t, i)| (t, i));
            for kind in [QueueKind::Heap, QueueKind::Calendar] {
                let mut q = EventQueue::with_kind(kind);
                for (i, &t) in pushes.iter().enumerate() {
                    q.schedule_at(t, i);
                }
                let mut got = Vec::new();
                while let Some((t, i)) = q.pop() {
                    got.push((t, i));
                }
                if got != expected {
                    return Err(format!(
                        "{kind:?}: FIFO order broken over {} events",
                        pushes.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// `len`, `is_empty`, and `scheduled_total` stay consistent with a simple
/// push/pop counter model at every step, and `peek_time` never runs
/// behind the clock.
#[test]
fn len_and_scheduled_total_accounting() {
    forall_r(
        "len-accounting",
        60,
        0xACC7,
        random_script,
        |script| {
            for kind in [QueueKind::Heap, QueueKind::Calendar] {
                let mut q = EventQueue::with_kind(kind);
                let mut pushed = 0u64;
                let mut popped = 0u64;
                for op in script {
                    match *op {
                        Op::Push { delay } => {
                            pushed += 1;
                            q.schedule_in(delay, ());
                        }
                        Op::Pop => {
                            if q.pop().is_some() {
                                popped += 1;
                            } else if !q.is_empty() {
                                return Err(format!(
                                    "{kind:?}: pop() returned None on a non-empty queue"
                                ));
                            }
                        }
                    }
                    if q.len() as u64 != pushed - popped {
                        return Err(format!(
                            "{kind:?}: len {} != pushed {pushed} - popped {popped}",
                            q.len()
                        ));
                    }
                    if q.is_empty() != (q.len() == 0) {
                        return Err(format!("{kind:?}: is_empty inconsistent with len"));
                    }
                    if q.scheduled_total() != pushed {
                        return Err(format!(
                            "{kind:?}: scheduled_total {} != pushed {pushed}",
                            q.scheduled_total()
                        ));
                    }
                    if let Some(pt) = q.peek_time() {
                        if pt < q.now() {
                            return Err(format!(
                                "{kind:?}: peek_time {pt} is before now {}",
                                q.now()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
