//! Statistical property wall for the open-loop arrival generators
//! (DESIGN.md §13).
//!
//! The generators are only useful if they are simultaneously (a) honest
//! samplers of the process they claim to be and (b) bit-deterministic
//! functions of the seed, invariant across event-engine backends.  The
//! tests here pin both: empirical rates and tail indices within tolerance
//! over large draws, and byte-stable draw sequences across seeds and all
//! four `{queue} × {store}` engine combinations.

use ds_rs::coordinator::run::{run_full, EngineOptions, RunOptions};
use ds_rs::sim::{QueueKind, SimRng, StoreKind, MINUTE};
use ds_rs::testutil::fixtures::{plate_jobs, quick_cfg, shaped, template_fleet};
use ds_rs::traffic::{ArrivalProcess, QueueingPolicy, TrafficSpec};

const DRAWS: usize = 100_000;

fn delays(process: &ArrivalProcess, seed: u64, n: usize) -> Vec<u64> {
    let mut rng = SimRng::new(seed);
    let mut now: u64 = 0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let d = process.next_delay_ms(&mut rng, now);
        now += d;
        out.push(d);
    }
    out
}

#[test]
fn poisson_empirical_rate_matches_lambda() {
    // λ = 2 jobs/min → mean inter-arrival 30 s = 30_000 ms.  Over 10⁵
    // draws the sample mean of an exponential is within ~1% at 3σ
    // (σ/√n ≈ 0.32%), so a 1% band is a comfortable, non-flaky gate.
    let process = ArrivalProcess::Poisson { rate_per_min: 2.0 };
    let ds = delays(&process, 42, DRAWS);
    let mean = ds.iter().sum::<u64>() as f64 / ds.len() as f64;
    let expect = 30_000.0;
    assert!(
        (mean - expect).abs() / expect < 0.01,
        "poisson mean delay {mean} ms, expected ~{expect} ms"
    );
    assert!((process.mean_rate_per_min() - 2.0).abs() < 1e-12);
}

#[test]
fn diurnal_phase_integrates_to_its_budget() {
    // rate(t) swings 0.5..2.0 per minute over a 120-minute period, so the
    // long-run average rate is (base + peak) / 2 = 1.25/min.  Count
    // arrivals over many whole periods and compare.
    let process = ArrivalProcess::Diurnal {
        base_per_min: 0.5,
        peak_per_min: 2.0,
        period_min: 120,
    };
    let mut rng = SimRng::new(7);
    let horizon: u64 = 200 * 120 * MINUTE; // 200 full periods
    let mut now: u64 = 0;
    let mut arrivals: u64 = 0;
    while now < horizon {
        now += process.next_delay_ms(&mut rng, now);
        arrivals += 1;
    }
    let rate = arrivals as f64 / (horizon as f64 / MINUTE as f64);
    assert!(
        (rate - 1.25).abs() < 0.05,
        "diurnal empirical rate {rate}/min, expected ~1.25/min"
    );
    assert!((process.mean_rate_per_min() - 1.25).abs() < 1e-12);

    // The phase structure is real, not just the mean: the busiest
    // half-period (centered on the crest) must see substantially more
    // arrivals than the quietest.  Bucket arrivals by phase.
    let mut rng = SimRng::new(11);
    let mut now: u64 = 0;
    let period_ms = 120 * MINUTE;
    let mut crest: u64 = 0; // phase in [1/4, 3/4) of the period
    let mut trough: u64 = 0;
    while now < horizon {
        now += process.next_delay_ms(&mut rng, now);
        let phase = (now % period_ms) as f64 / period_ms as f64;
        if (0.25..0.75).contains(&phase) {
            crest += 1;
        } else {
            trough += 1;
        }
    }
    assert!(
        crest as f64 > 1.5 * trough as f64,
        "diurnal crest {crest} vs trough {trough}: no day/night contrast"
    );
}

#[test]
fn pareto_tail_index_recovered_by_hill_estimator() {
    // The Hill estimator over the top k order statistics consistently
    // recovers the tail index α of a Pareto sample:
    //   α̂ = k / Σ_{i=1..k} ln(x_(i) / x_(k+1))   (x_(1) ≥ x_(2) ≥ …)
    let alpha = 1.5;
    let process = ArrivalProcess::HeavyTailed {
        alpha,
        scale_min: 0.1,
    };
    let mut xs: Vec<f64> = delays(&process, 99, DRAWS)
        .into_iter()
        .map(|ms| ms as f64 / MINUTE as f64)
        .collect();
    xs.sort_by(|a, b| b.partial_cmp(a).unwrap()); // descending
    let k = 1000;
    let tail = xs[k]; // x_(k+1)
    let sum: f64 = xs[..k].iter().map(|x| (x / tail).ln()).sum();
    let alpha_hat = k as f64 / sum;
    assert!(
        (alpha_hat - alpha).abs() / alpha < 0.15,
        "Hill estimate {alpha_hat}, expected ~{alpha}"
    );
    // α > 1 → the mean rate is finite and positive.
    assert!(process.mean_rate_per_min() > 0.0);
    // α ≤ 1 → the mean diverges and the advertised rate is 0.
    assert_eq!(
        (ArrivalProcess::HeavyTailed {
            alpha: 0.9,
            scale_min: 0.1
        })
        .mean_rate_per_min(),
        0.0
    );
}

#[test]
fn draw_sequences_are_seed_stable_with_pinned_bytes() {
    // Same seed → byte-identical draw sequence (debug formatting pins the
    // bytes without hard-coding generator constants); different seed →
    // different sequence.
    for process in [
        ArrivalProcess::Poisson { rate_per_min: 2.0 },
        ArrivalProcess::Diurnal {
            base_per_min: 0.5,
            peak_per_min: 2.0,
            period_min: 120,
        },
        ArrivalProcess::HeavyTailed {
            alpha: 1.5,
            scale_min: 0.1,
        },
    ] {
        let a = format!("{:?}", delays(&process, 1234, 512));
        let b = format!("{:?}", delays(&process, 1234, 512));
        let c = format!("{:?}", delays(&process, 1235, 512));
        assert_eq!(a, b, "{} draws not seed-stable", process.kind());
        assert_ne!(a, c, "{} draws ignore the seed", process.kind());
    }
}

fn all_engines() -> [EngineOptions; 4] {
    [
        EngineOptions {
            queue: QueueKind::Heap,
            store: StoreKind::Map,
        },
        EngineOptions {
            queue: QueueKind::Heap,
            store: StoreKind::Dense,
        },
        EngineOptions {
            queue: QueueKind::Calendar,
            store: StoreKind::Map,
        },
        EngineOptions {
            queue: QueueKind::Calendar,
            store: StoreKind::Dense,
        },
    ]
}

#[test]
fn traffic_runs_identical_across_engine_backends() {
    // A full multi-tenant run — arrivals drawn live, fair-share dispatch,
    // per-tenant accounting — is bit-identical under all four engine
    // combinations, and its JSON bytes too.
    let cfg = quick_cfg(3);
    let fleet = template_fleet();
    let jobs = plate_jobs(2, 1); // ignored: the traffic spec is the workload
    let run = |engine: EngineOptions| {
        let mut ex = shaped(45.0, 0.3, 0.0, 0.0);
        let opts = RunOptions {
            seed: 21,
            engine,
            traffic: TrafficSpec::shape("two-tenant"),
            queueing: QueueingPolicy::FairShare,
            ..Default::default()
        };
        run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap()
    };
    let reference = run(all_engines()[0]);
    assert_eq!(reference.traffic.traffic, "two-tenant");
    assert_eq!(reference.traffic.queueing, "fair-share");
    assert_eq!(reference.traffic.tenants.len(), 2);
    let total: u64 = reference.traffic.tenants.iter().map(|t| t.completed).sum();
    assert_eq!(total, TrafficSpec::shape("two-tenant").unwrap().total_jobs());
    for engine in &all_engines()[1..] {
        let report = run(*engine);
        assert_eq!(reference, report, "{engine:?}");
        assert_eq!(
            reference.to_json().to_string(),
            report.to_json().to_string(),
            "{engine:?} JSON bytes"
        );
    }
}
