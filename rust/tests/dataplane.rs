//! Data-plane invariants (DESIGN.md §7): byte conservation, max-min
//! fairness bounds, makespan monotonicity in `input_bytes`, and replay
//! of data-shaped runs and sweeps at any thread count.

use ds_rs::aws::s3::dataplane::{gbps_to_bytes_per_ms, DataPlane, Direction, NetProfile};
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::sim::MINUTE;
use ds_rs::testutil::fixtures;
use ds_rs::testutil::forall_r;
use ds_rs::workloads::{DurationModel, ModeledExecutor};

/// The shared small rig, with the data plane's historical 10-minute
/// visibility (long transfers must not churn redeliveries).
fn quick_cfg() -> AppConfig {
    let mut cfg = fixtures::quick_cfg(2);
    cfg.sqs_message_visibility = 10 * MINUTE;
    cfg
}

fn modeled(mean_s: f64) -> ModeledExecutor {
    fixtures::modeled(mean_s)
}

/// One random data-plane episode: flows arriving on random instances and
/// buckets, random advances, random instance cancellations.
#[derive(Debug, Clone)]
struct Episode {
    /// (start_gap_ms, instance, bucket_idx, upload, bytes)
    arrivals: Vec<(u64, u64, u8, bool, u64)>,
    /// Instances cancelled at the end, before draining.
    cancels: Vec<u64>,
}

#[test]
fn prop_byte_conservation() {
    // Bytes billed == bytes of completed flows + bytes wasted on
    // cancelled ones, and wasted never exceeds what the cancelled flows
    // could have moved — under arbitrary arrival/advance/cancel orders.
    forall_r(
        "dataplane-byte-conservation",
        40,
        0xB17E,
        |rng| Episode {
            arrivals: (0..(1 + rng.below(30)))
                .map(|_| {
                    (
                        rng.below(5_000),
                        rng.below(4),
                        rng.below(2) as u8,
                        rng.chance(0.4),
                        1 + rng.below(50_000_000),
                    )
                })
                .collect(),
            cancels: (0..rng.below(4)).map(|_| rng.below(4)).collect(),
        },
        |ep| {
            let mut plane = DataPlane::new(NetProfile::standard());
            let mut now = 0u64;
            let mut started: u64 = 0;
            let mut completed_bytes: u64 = 0;
            for &(gap, inst, bucket, upload, bytes) in &ep.arrivals {
                now += gap;
                let dir = if upload { Direction::Upload } else { Direction::Download };
                let bucket = if bucket == 0 { "a" } else { "b" };
                plane.start(now, inst, 1.25, bucket, dir, bytes);
                started += bytes;
                // Interleave: drain anything that finished on the way.
                for (_, end) in plane.poll(now) {
                    completed_bytes += end.bytes;
                }
            }
            let mut cancelled_possible: u64 = 0;
            for &inst in &ep.cancels {
                // Upper bound on what the cancelled flows could bill.
                cancelled_possible += plane
                    .cancel_instance(now, inst)
                    .len() as u64
                    * 50_000_001;
            }
            while let Some(t) = plane.next_event() {
                for (_, end) in plane.poll(t) {
                    completed_bytes += end.bytes;
                }
            }
            let st = plane.stats();
            let billed = st.bytes_downloaded + st.bytes_uploaded;
            if billed != completed_bytes + st.bytes_wasted {
                return Err(format!(
                    "billed {billed} != completed {completed_bytes} + wasted {}",
                    st.bytes_wasted
                ));
            }
            if billed > started {
                return Err(format!("billed {billed} > started {started}"));
            }
            if st.bytes_wasted > cancelled_possible {
                return Err(format!(
                    "wasted {} exceeds cancelled flows' bytes (≤ {cancelled_possible})",
                    st.bytes_wasted
                ));
            }
            if plane.in_flight() != 0 {
                return Err(format!("{} flows never finished", plane.in_flight()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_max_min_fair_share_lower_bound() {
    // With every flow backlogged, no flow's planned rate falls below the
    // global fair share min(cap_link / members_link) — the max-min
    // guarantee — and no link's total allocation exceeds its capacity.
    forall_r(
        "dataplane-fair-share",
        40,
        0xFA1A,
        |rng| {
            let n = 2 + rng.below(12);
            (0..n)
                .map(|_| (rng.below(3), rng.below(2) as u8))
                .collect::<Vec<(u64, u8)>>()
        },
        |flows| {
            let profile = NetProfile::standard();
            let mut plane = DataPlane::new(profile.clone());
            let nic = 1.25f64;
            let ids: Vec<u64> = flows
                .iter()
                .map(|&(inst, bucket)| {
                    plane.start(
                        0,
                        inst,
                        nic,
                        if bucket == 0 { "a" } else { "b" },
                        Direction::Download,
                        1_000_000_000, // 1 GB: backlogged throughout
                    )
                })
                .collect();
            // Activate everything, then inspect the plan.
            plane.poll(profile.first_byte_ms);
            // Global fair share: the most contended link's cap / members.
            let nic_cap = gbps_to_bytes_per_ms(nic);
            let bucket_cap = profile.bucket_bytes_per_ms();
            let mut min_share = f64::INFINITY;
            for inst in 0..3u64 {
                let members = flows.iter().filter(|&&(i, _)| i == inst).count();
                if members > 0 {
                    min_share = min_share.min(nic_cap / members as f64);
                }
            }
            for bucket in 0..2u8 {
                let members = flows.iter().filter(|&&(_, b)| b == bucket).count();
                if members > 0 {
                    min_share = min_share.min(bucket_cap / members as f64);
                }
            }
            for (&id, &(inst, bucket)) in ids.iter().zip(flows) {
                let rate = plane
                    .rate_of(id)
                    .ok_or_else(|| format!("flow {id} vanished"))?;
                if rate < min_share - 1e-6 {
                    return Err(format!(
                        "flow {id} (inst {inst}, bucket {bucket}) at {rate} below fair share {min_share}"
                    ));
                }
            }
            // Capacity conservation per link.
            for inst in 0..3u64 {
                let total: f64 = ids
                    .iter()
                    .zip(flows)
                    .filter(|&(_, &(i, _))| i == inst)
                    .map(|(&id, _)| plane.rate_of(id).unwrap_or(0.0))
                    .sum();
                if total > nic_cap + 1e-6 {
                    return Err(format!("NIC {inst} oversubscribed: {total} > {nic_cap}"));
                }
            }
            for bucket in 0..2u8 {
                let total: f64 = ids
                    .iter()
                    .zip(flows)
                    .filter(|&(_, &(_, b))| b == bucket)
                    .map(|(&id, _)| plane.rate_of(id).unwrap_or(0.0))
                    .sum();
                if total > bucket_cap + 1e-6 {
                    return Err(format!("bucket {bucket} oversubscribed: {total}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn makespan_monotone_in_input_bytes() {
    // Same seed, same bandwidth: more bytes per job can only push the
    // drain later.
    let cfg = quick_cfg();
    let fleet = FleetSpec::template("us-east-1").unwrap();
    let mut last = 0u64;
    for &mb in &[0u64, 16, 64, 256] {
        let jobs = JobSpec::plate("P", 4, 2, vec![]).with_uniform_data(mb * 1_000_000, mb * 125_000);
        let mut ex = modeled(60.0);
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
        let makespan = report
            .drained_at
            .unwrap_or_else(|| panic!("undrained at {mb} MB: {}", report.summary()));
        assert!(
            makespan >= last,
            "makespan shrank when inputs grew to {mb} MB: {makespan} < {last}"
        );
        assert_eq!(report.stats.completed, 8, "{}", report.summary());
        last = makespan;
    }
    assert!(last > 0);
}

#[test]
fn data_sweep_bit_identical_at_1_2_8_threads() {
    use ds_rs::coordinator::sweep::{run_sweep, ScenarioMatrix, SweepPlan};
    let matrix = ScenarioMatrix {
        seeds: vec![11, 12],
        cluster_machines: vec![1, 2],
        input_mbs: vec![0.0, 48.0],
        net_profiles: vec![NetProfile::standard(), NetProfile::narrow()],
        models: vec![DurationModel {
            mean_s: 30.0,
            cv: 0.2,
            ..Default::default()
        }],
        ..Default::default()
    };
    let plan = SweepPlan::new(quick_cfg(), JobSpec::plate("P", 4, 1, vec![]), matrix);
    let one = run_sweep(&plan, 1).unwrap();
    let two = run_sweep(&plan, 2).unwrap();
    let eight = run_sweep(&plan, 8).unwrap();
    assert_eq!(one.report, two.report);
    assert_eq!(one.report, eight.report);
    assert_eq!(one.cells, two.cells);
    assert_eq!(one.cells, eight.cells);
    // The data axes actually exercised the plane somewhere.
    assert!(
        one.report
            .scenarios
            .iter()
            .any(|s| s.data.bytes_downloaded > 0),
        "no scenario moved bytes"
    );
    // And zero-data scenarios stayed zero.
    assert!(one
        .report
        .scenarios
        .iter()
        .any(|s| s.data.bytes_downloaded == 0));
}
