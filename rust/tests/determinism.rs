//! Determinism regression gates (DESIGN.md §6).
//!
//! The whole experiment methodology rests on two facts: (1) one seed
//! replays one run bit-identically, and (2) the parallel sweep engine is
//! a pure function of its plan — worker-thread count affects wall-clock
//! only, never a single bit of the output.  These tests pin both.

use ds_rs::aws::ec2::{AllocationStrategy, InstanceSlot};
use ds_rs::config::AppConfig;
use ds_rs::coordinator::autoscale::ScalingMode;
use ds_rs::coordinator::run::{run_full, EngineOptions, RunOptions};
use ds_rs::coordinator::sweep::{run_sweep, ScenarioMatrix, SweepPlan};
use ds_rs::metrics::RunReport;
use ds_rs::sim::{QueueKind, StoreKind, MINUTE};
use ds_rs::testutil::fixtures::{plate_jobs, quick_cfg, shaped, template_fleet};
use ds_rs::workloads::{DurationModel, ModeledExecutor};

fn cfg() -> AppConfig {
    quick_cfg(3)
}

/// All four engine combinations.  Index 0 is the reference engine (binary
/// heap + hash maps) every fast path is gated against.
fn all_engines() -> [EngineOptions; 4] {
    [
        EngineOptions {
            queue: QueueKind::Heap,
            store: StoreKind::Map,
        },
        EngineOptions {
            queue: QueueKind::Heap,
            store: StoreKind::Dense,
        },
        EngineOptions {
            queue: QueueKind::Calendar,
            store: StoreKind::Map,
        },
        EngineOptions {
            queue: QueueKind::Calendar,
            store: StoreKind::Dense,
        },
    ]
}

fn serial_run(seed: u64) -> RunReport {
    serial_run_with(seed, EngineOptions::default())
}

fn serial_run_with(seed: u64, engine: EngineOptions) -> RunReport {
    let jobs = plate_jobs(8, 2);
    let mut ex = shaped(45.0, 0.3, 0.02, 0.05);
    let opts = RunOptions {
        seed,
        engine,
        ..Default::default()
    };
    run_full(&cfg(), &jobs, &fleet(), &mut ex, opts).unwrap()
}

fn fleet() -> ds_rs::config::FleetSpec {
    template_fleet()
}

#[test]
fn same_seed_replays_bit_identical_runreport() {
    // Full-struct equality: stats, drain/end times, cleanup flag, every
    // cost line item, and the submitted count.
    let a = serial_run(7);
    let b = serial_run(7);
    assert_eq!(a, b);
}

#[test]
fn different_seeds_diverge() {
    // Guards against the seed being silently ignored (which would make
    // the bit-identity test above vacuous).
    let a = serial_run(7);
    let b = serial_run(8);
    assert_ne!(a, b);
}

fn sweep_plan() -> SweepPlan {
    let jobs = plate_jobs(6, 2); // 12 jobs per cell
    let matrix = ScenarioMatrix {
        seeds: (0..8).collect(),
        cluster_machines: vec![2, 4],
        models: vec![DurationModel {
            mean_s: 40.0,
            cv: 0.3,
            ..Default::default()
        }],
        ..Default::default()
    };
    SweepPlan::new(cfg(), jobs, matrix)
}

#[test]
fn sweep_report_identical_at_1_2_and_8_threads() {
    let plan = sweep_plan();
    let one = run_sweep(&plan, 1).unwrap();
    let two = run_sweep(&plan, 2).unwrap();
    let eight = run_sweep(&plan, 8).unwrap();
    // Aggregates are bit-identical...
    assert_eq!(one.report, two.report);
    assert_eq!(one.report, eight.report);
    // ...because every underlying cell is, in the same order.
    assert_eq!(one.cells, two.cells);
    assert_eq!(one.cells, eight.cells);
}

#[test]
fn sweep_cell_matches_standalone_run() {
    // A sweep cell is exactly run_full with the scenario knobs overlaid —
    // no hidden coupling between cells.
    let plan = sweep_plan();
    let run = run_sweep(&plan, 4).unwrap();
    let cell = &run.cells[0];
    let sc = &run.scenarios[cell.scenario];

    let mut cfg = plan.base_cfg.clone();
    cfg.cluster_machines = sc.machines;
    cfg.sqs_message_visibility = sc.visibility;
    let mut fleet = plan.fleet.clone();
    fleet.allocation_strategy = sc.allocation;
    if !sc.instance_set.is_empty() {
        fleet.instance_types = sc.instance_set.clone();
    }
    let mut ex = ModeledExecutor {
        model: sc.model.clone(),
        ..Default::default()
    };
    let opts = RunOptions {
        seed: cell.seed,
        volatility: sc.volatility,
        ..Default::default()
    };
    let standalone = run_full(&cfg, &plan.jobs, &fleet, &mut ex, opts).unwrap();
    assert_eq!(cell.report, standalone);
}

/// The heterogeneous-fleet axes (allocation strategy × instance set, with
/// weighted slots and an on-demand base) must not disturb the
/// thread-count invariance: one plan, one bit-identical report.
fn heterogeneous_sweep_plan() -> SweepPlan {
    let mut base = cfg();
    base.machine_price = 0.20; // per weighted unit
    let jobs = plate_jobs(5, 2); // 10 jobs per cell
    let matrix = ScenarioMatrix {
        seeds: (0..4).collect(),
        cluster_machines: vec![3],
        volatilities: vec![ds_rs::aws::ec2::Volatility::Medium],
        allocations: AllocationStrategy::ALL.to_vec(),
        instance_sets: vec![
            vec![
                InstanceSlot::new("m5.large"),
                InstanceSlot {
                    name: "m5.xlarge".into(),
                    weight: 2,
                },
                InstanceSlot::new("c5.xlarge"),
            ],
        ],
        models: vec![DurationModel {
            mean_s: 40.0,
            cv: 0.3,
            ..Default::default()
        }],
        ..Default::default()
    };
    let mut plan = SweepPlan::new(base, jobs, matrix);
    plan.fleet.on_demand_base = 1;
    plan
}

/// Scenario API v2 gate: the same matrix expressed through the builder
/// and through a rendered-then-reparsed Sweep file is the *same plan* —
/// byte-identical labels and a bit-identical report at 1/2/8 threads.
/// This is what lets a committed Sweep file double as a regression gate.
#[test]
fn builder_and_sweep_file_paths_are_bit_identical() {
    use ds_rs::scenario::SweepFile;
    let plan = ds_rs::coordinator::sweep::SweepPlan::builder()
        .config(cfg())
        .jobs(plate_jobs(6, 2))
        .seeds(0..8)
        .machines([2, 4])
        // The builder inherits visibility from the config (like the
        // CLI); the legacy struct literal used the fixed default.  Pin
        // it so both plans describe the same matrix.
        .visibilities([10 * MINUTE])
        .models([DurationModel {
            mean_s: 40.0,
            cv: 0.3,
            ..Default::default()
        }])
        .build()
        .unwrap();
    // The builder plan equals the hand-assembled legacy plan.
    let legacy = sweep_plan();
    let from_builder = run_sweep(&plan, 2).unwrap();
    let from_legacy = run_sweep(&legacy, 2).unwrap();
    assert_eq!(from_builder.report, from_legacy.report);
    assert_eq!(from_builder.cells, from_legacy.cells);
    // ...and survives the Sweep-file round trip at every thread count.
    let reparsed = SweepFile::from_text(&SweepFile::render(&plan))
        .unwrap()
        .to_plan()
        .unwrap();
    let one = run_sweep(&reparsed, 1).unwrap();
    let eight = run_sweep(&reparsed, 8).unwrap();
    assert_eq!(one.report, from_builder.report);
    assert_eq!(eight.report, from_builder.report);
    assert_eq!(one.cells, from_builder.cells);
}

#[test]
fn heterogeneous_sweep_identical_at_1_2_and_8_threads() {
    let plan = heterogeneous_sweep_plan();
    let one = run_sweep(&plan, 1).unwrap();
    let two = run_sweep(&plan, 2).unwrap();
    let eight = run_sweep(&plan, 8).unwrap();
    assert_eq!(one.report, two.report);
    assert_eq!(one.report, eight.report);
    assert_eq!(one.cells, two.cells);
    assert_eq!(one.cells, eight.cells);
    // Sanity: the axes actually produced three distinct scenarios with
    // per-pool activity in every report.
    assert_eq!(one.report.scenarios.len(), 3);
    for s in &one.report.scenarios {
        assert!(!s.pools.is_empty(), "no pool rows for '{}'", s.label);
        assert!(s.pools.iter().any(|p| p.pool.ends_with("/on-demand")));
    }
}

/// The scaling axes join the thread-count invariance gate: a sweep over
/// fixed vs target-tracking vs step policies is bit-identical at 1/2/8
/// threads, and the elastic cells actually moved the fleet.
#[test]
fn scaling_sweep_identical_at_1_2_and_8_threads() {
    let jobs = plate_jobs(12, 2); // 24 jobs per cell
    let matrix = ScenarioMatrix {
        seeds: (0..3).collect(),
        cluster_machines: vec![4],
        scalings: ScalingMode::ALL.to_vec(),
        // A high per-unit target makes the scale-in band wide, so the
        // elastic cells shrink well before the tail (deterministically
        // across seeds), not just at the last job.
        scaling_targets: vec![8.0],
        models: vec![DurationModel {
            mean_s: 300.0,
            cv: 0.3,
            ..Default::default()
        }],
        ..Default::default()
    };
    let plan = SweepPlan::new(cfg(), jobs, matrix);
    let one = run_sweep(&plan, 1).unwrap();
    let two = run_sweep(&plan, 2).unwrap();
    let eight = run_sweep(&plan, 8).unwrap();
    assert_eq!(one.report, two.report);
    assert_eq!(one.report, eight.report);
    assert_eq!(one.cells, two.cells);
    assert_eq!(one.cells, eight.cells);
    // Three distinct scenarios, policies threaded into the summaries.
    let policies: Vec<&str> = one
        .report
        .scenarios
        .iter()
        .map(|s| s.scaling.policy.as_str())
        .collect();
    assert_eq!(policies, vec!["none", "target-tracking", "step"]);
    for s in &one.report.scenarios {
        // Elasticity never loses work: every cell completes its jobs.
        assert!(s.completed + s.skipped_done + s.dead_lettered >= 72, "{s:?}");
    }
    // The elastic scenarios scaled in while the queue drained.
    assert!(
        one.report.scenarios[1].scaling.decisions > 0,
        "target-tracking never decided: {:?}",
        one.report.scenarios[1].scaling
    );
}

// ---------------------------------------------------------------------------
// Engine A/B equivalence gate (DESIGN.md §"Event core").
//
// The calendar event queue and the dense id-indexed entity stores are
// *replacements* for the binary heap and the hash maps, so the bar is not
// "also correct" but "bit-identical": every determinism scenario must
// produce byte-for-byte the same RunReport (and sweep JSON) under all
// four `{queue} × {store}` combinations.  These tests are what allowed
// the fast paths to become the defaults.

#[test]
fn engine_backends_bit_identical_on_serial_runs() {
    for seed in [7, 11] {
        let reference = serial_run_with(seed, all_engines()[0]);
        for engine in &all_engines()[1..] {
            assert_eq!(reference, serial_run_with(seed, *engine), "{engine:?} seed {seed}");
        }
    }
}

#[test]
fn engine_backends_bit_identical_on_data_shaped_crashy_runs() {
    // Data-plane flows (park/cancel on the flow table) plus crashes and
    // alarm reaping (instance deregistration, container teardown) hit
    // every arena/store mutation path.
    let cfg = cfg();
    let fleet = fleet();
    let jobs = plate_jobs(6, 2).with_uniform_data(32_000_000, 4_000_000);
    let run = |engine: EngineOptions| {
        let mut ex = shaped(45.0, 0.3, 0.02, 0.05);
        let opts = RunOptions {
            seed: 13,
            volatility: ds_rs::aws::ec2::Volatility::High,
            crash_mttf: Some(40 * MINUTE),
            net: ds_rs::aws::s3::dataplane::NetProfile::narrow(),
            engine,
            ..Default::default()
        };
        run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap()
    };
    let reference = run(all_engines()[0]);
    assert!(reference.data.total_bytes() > 0);
    for engine in &all_engines()[1..] {
        assert_eq!(reference, run(*engine), "{engine:?}");
    }
}

#[test]
fn engine_backends_bit_identical_across_sweep_threads() {
    let mut reference_plan = sweep_plan();
    reference_plan.base_opts.engine = all_engines()[0];
    let reference = run_sweep(&reference_plan, 2).unwrap();
    for engine in all_engines() {
        let mut plan = sweep_plan();
        plan.base_opts.engine = engine;
        for threads in [1, 2, 8] {
            let run = run_sweep(&plan, threads).unwrap();
            assert_eq!(reference.report, run.report, "{engine:?} @ {threads} threads");
            assert_eq!(reference.cells, run.cells, "{engine:?} @ {threads} threads");
            // Byte-level: the exported sweep JSON is identical too.
            assert_eq!(
                reference.report.to_json().to_string(),
                run.report.to_json().to_string(),
                "{engine:?} @ {threads} threads"
            );
        }
    }
}

#[test]
fn engine_backends_bit_identical_on_scaling_and_data_axes_sweep() {
    let jobs = plate_jobs(5, 2); // 10 jobs per cell
    let matrix = ScenarioMatrix {
        seeds: (0..2).collect(),
        cluster_machines: vec![3],
        scalings: ScalingMode::ALL.to_vec(),
        scaling_targets: vec![8.0],
        input_mbs: vec![0.0, 24.0],
        models: vec![DurationModel {
            mean_s: 120.0,
            cv: 0.3,
            ..Default::default()
        }],
        ..Default::default()
    };
    let mk = |engine: EngineOptions| {
        let mut plan = SweepPlan::new(cfg(), jobs.clone(), matrix.clone());
        plan.base_opts.engine = engine;
        plan
    };
    let reference = run_sweep(&mk(all_engines()[0]), 2).unwrap();
    // Sanity: the axes actually exercised scaling and the data plane.
    assert!(reference
        .report
        .scenarios
        .iter()
        .any(|s| s.scaling.policy == "target-tracking"));
    assert!(reference.cells.iter().any(|c| c.report.data.total_bytes() > 0));
    for engine in &all_engines()[1..] {
        for threads in [1, 8] {
            let run = run_sweep(&mk(*engine), threads).unwrap();
            assert_eq!(reference.report, run.report, "{engine:?} @ {threads} threads");
            assert_eq!(reference.cells, run.cells, "{engine:?} @ {threads} threads");
        }
    }
}

// ---------------------------------------------------------------------------
// DAG workflow gates (DESIGN.md §11).
//
// The readiness scheduler adds a second wave of SQS sends *during* the
// run (children released as parents commit), so it gets the same wall
// the flat path earned: thread-count invariance, engine A/B equivalence,
// and declaration-order independence.

/// A workflow sweep over shape × sharing mode is bit-identical at 1/2/8
/// worker threads under every `{queue} × {store}` engine combination —
/// the mid-run release sends must not introduce any ordering the seed
/// does not fully determine.
#[test]
fn workflow_sweep_identical_across_threads_and_engines() {
    use ds_rs::workflow::SharingMode;
    use ds_rs::workloads::dag;
    let mk = |engine: EngineOptions| {
        let mut plan = SweepPlan::builder()
            .config(cfg())
            // Workflow cells ignore the Job file: the DAG is the workload.
            .jobs(plate_jobs(2, 1))
            .seeds(0..2)
            .workflows([Some(dag::diamond()), Some(dag::mosaic())])
            .sharings(SharingMode::ALL)
            .models([DurationModel {
                mean_s: 40.0,
                cv: 0.3,
                ..Default::default()
            }])
            .build()
            .unwrap();
        plan.base_opts.engine = engine;
        plan
    };
    let reference = run_sweep(&mk(all_engines()[0]), 2).unwrap();
    // Sanity: 2 shapes x 3 sharing modes, every cell ran its whole DAG
    // and the workflow breakdown made it into the aggregates.
    assert_eq!(reference.report.scenarios.len(), 6);
    for s in &reference.report.scenarios {
        assert!(s.workflow.nodes > 0, "no workflow identity in '{}'", s.label);
        assert!(s.workflow.releases > 0, "no releases in '{}'", s.label);
        assert_eq!(s.completed, s.workflow.nodes * 2, "{}", s.label);
    }
    for engine in all_engines() {
        for threads in [1, 2, 8] {
            let run = run_sweep(&mk(engine), threads).unwrap();
            assert_eq!(reference.report, run.report, "{engine:?} @ {threads} threads");
            assert_eq!(reference.cells, run.cells, "{engine:?} @ {threads} threads");
            // Byte-level: the exported sweep JSON is identical too.
            assert_eq!(
                reference.report.to_json().to_string(),
                run.report.to_json().to_string(),
                "{engine:?} @ {threads} threads"
            );
        }
    }
}

/// A topology sweep over faulted layouts × every placement policy is
/// bit-identical at 1/2/8 worker threads under every `{queue} × {store}`
/// engine combination — correlated fault events, per-domain price walks,
/// and cross-region data flows must not introduce any ordering the seed
/// does not fully determine.
#[test]
fn topology_sweep_identical_across_threads_and_engines() {
    use ds_rs::topology::{ClusterTopology, FaultKind, Placement};
    let faulted = ClusterTopology::builder("two-region")
        .domain("us-east-1a", "us-east-1")
        .domain("us-west-2a", "us-west-2")
        .fault(FaultKind::AzOutage, "us-east-1a", 10, 60, 1.0)
        .fault(FaultKind::PriceStorm, "us-west-2a", 5, 120, 4.0)
        .build()
        .unwrap();
    let mk = |engine: EngineOptions| {
        let mut plan = SweepPlan::builder()
            .config(cfg())
            // Data-shaped jobs, so cross-region flows are in play.
            .jobs(plate_jobs(6, 2).with_uniform_data(8_000_000, 1_000_000))
            .seeds(0..2)
            .topologies([ClusterTopology::shape("three-az"), Some(faulted.clone())])
            .placements(Placement::ALL)
            .models([DurationModel {
                mean_s: 40.0,
                cv: 0.3,
                ..Default::default()
            }])
            .build()
            .unwrap();
        plan.base_opts.engine = engine;
        plan
    };
    let reference = run_sweep(&mk(all_engines()[0]), 2).unwrap();
    // Sanity: 2 topologies x 3 placements, every cell carried its
    // topology identity into the aggregates and finished its jobs.
    assert_eq!(reference.report.scenarios.len(), 6);
    for s in &reference.report.scenarios {
        assert!(
            !s.topology.domains.is_empty(),
            "no topology identity in '{}'",
            s.label
        );
        assert!(s.completed > 0, "{}", s.label);
    }
    for engine in all_engines() {
        for threads in [1, 2, 8] {
            let run = run_sweep(&mk(engine), threads).unwrap();
            assert_eq!(reference.report, run.report, "{engine:?} @ {threads} threads");
            assert_eq!(reference.cells, run.cells, "{engine:?} @ {threads} threads");
            // Byte-level: the exported sweep JSON is identical too.
            assert_eq!(
                reference.report.to_json().to_string(),
                run.report.to_json().to_string(),
                "{engine:?} @ {threads} threads"
            );
        }
    }
}

/// Scheduling is a function of the DAG, not of how it was written down:
/// permuting the job and edge declaration lists changes neither the
/// fingerprint nor — with a constant-duration executor, so sampling
/// order carries no noise — a single byte of the run report.  Every
/// canonical shape keeps same-depth peers byte-symmetric precisely so
/// this holds under core contention.
#[test]
fn topological_declaration_order_does_not_change_report_bytes() {
    use ds_rs::workflow::WorkflowSpec;
    use ds_rs::workloads::dag;

    fn permuted(spec: &WorkflowSpec, rot: usize, rev: bool) -> WorkflowSpec {
        let mut jobs = spec.jobs.clone();
        let mut edges = spec.edges.clone();
        jobs.rotate_left(rot % jobs.len());
        if !edges.is_empty() {
            edges.rotate_left((rot * 3) % edges.len());
        }
        if rev {
            jobs.reverse();
            edges.reverse();
        }
        WorkflowSpec::new(&spec.name, jobs, edges).expect("permutations stay valid")
    }

    let run_spec = |spec: WorkflowSpec| {
        let mut ex = shaped(60.0, 0.0, 0.0, 0.0); // constant durations
        let opts = RunOptions {
            seed: 5,
            workflow: Some(spec),
            ..Default::default()
        };
        run_full(&cfg(), &plate_jobs(2, 1), &fleet(), &mut ex, opts).unwrap()
    };

    for shape in [dag::diamond(), dag::fan_out_in(), dag::linear(), dag::mosaic()] {
        let reference = run_spec(shape.clone());
        assert_eq!(
            reference.stats.completed,
            shape.jobs.len() as u64,
            "{} did not complete",
            shape.name
        );
        for (rot, rev) in [(1, false), (2, true), (0, true)] {
            let p = permuted(&shape, rot, rev);
            assert_eq!(
                p.fingerprint(),
                shape.fingerprint(),
                "{} rot={rot} rev={rev} fingerprint",
                shape.name
            );
            assert_eq!(p.critical_path_len(), shape.critical_path_len());
            let report = run_spec(p);
            assert_eq!(reference, report, "{} rot={rot} rev={rev}", shape.name);
            assert_eq!(
                reference.to_json().to_string(),
                report.to_json().to_string(),
                "{} rot={rot} rev={rev} JSON bytes",
                shape.name
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-tenant open-loop traffic gates (DESIGN.md §13).
//
// Traffic runs replace the t=0 batch with per-tenant generators that
// enqueue SQS messages throughout the run, and non-FIFO queueing changes
// which message a free core picks.  Both are new orderings the seed must
// fully determine, so they get the same wall: thread-count invariance
// and engine A/B equivalence over the full traffic × queueing matrix.

/// A traffic sweep over arrival shapes × every queueing policy is
/// bit-identical at 1/2/8 worker threads under every `{queue} × {store}`
/// engine combination — generator draws and tenant-aware dispatch must
/// not introduce any ordering the seed does not fully determine.
#[test]
fn traffic_sweep_identical_across_threads_and_engines() {
    use ds_rs::traffic::{QueueingPolicy, TrafficSpec};
    let mk = |engine: EngineOptions| {
        let mut plan = SweepPlan::builder()
            .config(cfg())
            // Traffic cells ignore the Job file: the generators are the
            // workload.
            .jobs(plate_jobs(2, 1))
            .seeds(0..2)
            .traffics([
                TrafficSpec::shape("two-tenant"),
                TrafficSpec::shape("noisy-neighbor"),
            ])
            .queueings(QueueingPolicy::ALL)
            .models([DurationModel {
                mean_s: 40.0,
                cv: 0.3,
                ..Default::default()
            }])
            .build()
            .unwrap();
        plan.base_opts.engine = engine;
        plan
    };
    let reference = run_sweep(&mk(all_engines()[0]), 2).unwrap();
    // Sanity: 2 traffic shapes x 3 queueing policies, every cell carried
    // its tenant identity into the aggregates and finished its jobs.
    assert_eq!(reference.report.scenarios.len(), 6);
    for s in &reference.report.scenarios {
        assert_eq!(s.traffic.tenants.len(), 2, "no tenant rows in '{}'", s.label);
        let submitted: u64 = s.traffic.tenants.iter().map(|t| t.submitted).sum();
        let completed: u64 = s.traffic.tenants.iter().map(|t| t.completed).sum();
        assert!(submitted > 0, "{}", s.label);
        assert_eq!(completed, s.completed, "{}", s.label);
    }
    for engine in all_engines() {
        for threads in [1, 2, 8] {
            let run = run_sweep(&mk(engine), threads).unwrap();
            assert_eq!(reference.report, run.report, "{engine:?} @ {threads} threads");
            assert_eq!(reference.cells, run.cells, "{engine:?} @ {threads} threads");
            // Byte-level: the exported sweep JSON is identical too.
            assert_eq!(
                reference.report.to_json().to_string(),
                run.report.to_json().to_string(),
                "{engine:?} @ {threads} threads"
            );
        }
    }
}

/// The legacy-compatibility gate the axis promises: `--traffic single`
/// parses to *no* traffic spec, so a plan that says "single" explicitly
/// and a plan that never mentions traffic produce byte-identical sweep
/// JSON — pre-traffic output is untouched.
#[test]
fn traffic_single_sweep_bytes_match_the_traffic_free_plan() {
    let explicit = {
        let mut plan = sweep_plan();
        plan.matrix.traffics = vec![None]; // what `--traffic single` parses to
        plan
    };
    let a = run_sweep(&explicit, 2).unwrap();
    let b = run_sweep(&sweep_plan(), 2).unwrap();
    assert_eq!(a.report, b.report);
    assert_eq!(a.cells, b.cells);
    assert_eq!(
        a.report.to_json().to_string(),
        b.report.to_json().to_string()
    );
    // And the legacy JSON shape is intact: no traffic key anywhere.
    for s in &a.report.scenarios {
        assert!(s.to_json().get("traffic").is_none(), "{}", s.label);
    }
}
