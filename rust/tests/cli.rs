//! Integration: the `ds` binary — the run.py-shaped UX itself.

use std::process::Command;

fn ds() -> Command {
    Command::new(env!("CARGO_BIN_EXE_ds"))
}

fn run_ok(args: &[&str]) -> String {
    let out = ds().args(args).output().expect("spawn ds");
    assert!(
        out.status.success(),
        "ds {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn usage_lists_commands() {
    let out = run_ok(&[]);
    for cmd in ["make-config", "make-fleet-file", "make-job", "describe", "run"] {
        assert!(out.contains(cmd), "usage missing {cmd}: {out}");
    }
}

#[test]
fn usage_and_help_list_full_sweep_flag_set() {
    // The usage text and `ds sweep --help` document every *registered*
    // sweep flag: the assertion iterates the axis registry itself, so a
    // new axis that forgets its flag spec (or a help renderer that
    // drops one) fails here, and the docs can't drift from the strict
    // parser (typos are rejected against the same registry).
    for out in [run_ok(&[]), run_ok(&["sweep", "--help"])] {
        for f in ds_rs::scenario::sweep_flags() {
            assert!(
                out.contains(&format!("--{}", f.flag)),
                "sweep flag --{} undocumented in: {out}",
                f.flag
            );
        }
    }
}

#[test]
fn sweep_rejects_unknown_flag() {
    let out = ds().args(["sweep", "--machnies", "2,4"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown flag --machnies"), "{err}");
    assert!(err.contains("sweep --help"), "{err}");
}

#[test]
fn run_and_make_fleet_file_have_help() {
    let run_help = run_ok(&["run", "--help"]);
    for f in ds_rs::scenario::run_flags() {
        assert!(
            run_help.contains(&format!("--{}", f.flag)),
            "run flag --{} undocumented: {run_help}",
            f.flag
        );
    }
    let fleet_help = run_ok(&["make-fleet-file", "--help"]);
    for key in ["INSTANCE_TYPES", "ALLOCATION_STRATEGY", "ON_DEMAND_BASE"] {
        assert!(fleet_help.contains(key), "fleet key {key} undocumented: {fleet_help}");
    }
}

#[test]
fn unknown_command_fails_with_hint() {
    let out = ds().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn make_files_then_full_run() {
    let dir = std::env::temp_dir().join(format!("ds-cli-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let p = |name: &str| dir.join(name).to_str().unwrap().to_string();

    run_ok(&[
        "make-config",
        "--app-name",
        "CliTest",
        "--machines",
        "2",
        "--out",
        &p("config.json"),
    ]);
    run_ok(&["make-fleet-file", "--region", "us-east-1", "--out", &p("fleet.json")]);
    run_ok(&[
        "make-job",
        "--plate",
        "P1",
        "--wells",
        "4",
        "--sites",
        "2",
        "--out",
        &p("job.json"),
    ]);

    // describe validates and echoes the config.
    let desc = run_ok(&["describe", "--config", &p("config.json")]);
    assert!(desc.contains("\"APP_NAME\": \"CliTest\""));
    assert!(desc.contains("task_family=CliTest-taskdef"));

    // Full modeled run: 8 jobs, monitor cleanup, deterministic seed.
    let out = run_ok(&[
        "run",
        "--config",
        &p("config.json"),
        "--job",
        &p("job.json"),
        "--fleet",
        &p("fleet.json"),
        "--seed",
        "5",
        "--job-mean-s",
        "30",
    ]);
    assert!(out.contains("8/8 completed"), "{out}");
    assert!(out.contains("cleaned_up=true"), "{out}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_json_output_parses_and_carries_scaling() {
    let dir = std::env::temp_dir().join(format!("ds-cli-runjson-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let p = |name: &str| dir.join(name).to_str().unwrap().to_string();
    run_ok(&["make-config", "--machines", "2", "--out", &p("config.json")]);
    run_ok(&["make-fleet-file", "--out", &p("fleet.json")]);
    run_ok(&["make-job", "--wells", "4", "--sites", "2", "--out", &p("job.json")]);
    let out = run_ok(&[
        "run",
        "--config",
        &p("config.json"),
        "--job",
        &p("job.json"),
        "--fleet",
        &p("fleet.json"),
        "--seed",
        "5",
        "--job-mean-s",
        "30",
        "--scaling",
        "target-tracking",
        "--scaling-target",
        "2",
        "--json",
    ]);
    // With --json, stdout is exactly one JSON object.
    let v = ds_rs::json::parse(out.trim()).unwrap();
    assert_eq!(
        v.get("jobs_submitted").and_then(ds_rs::json::Value::as_u64),
        Some(8)
    );
    let stats = v.get("stats").unwrap();
    assert_eq!(
        stats.get("completed").and_then(ds_rs::json::Value::as_u64),
        Some(8)
    );
    let scaling = v.get("scaling").unwrap();
    assert_eq!(
        scaling.get("policy").and_then(ds_rs::json::Value::as_str),
        Some("target-tracking")
    );
    assert!(scaling.get("timeline").and_then(ds_rs::json::Value::as_arr).is_some());
    assert!(v.get("cost").and_then(|c| c.get("total_usd")).is_some());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_and_sweep_reject_bad_scaling_values() {
    let out = ds().args(["sweep", "--scaling", "sometimes"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("scaling"));
    let out = ds().args(["sweep", "--scaling-target", "0"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("scaling-target"));
}

#[test]
fn sweep_prints_scenario_table() {
    // 2 scenarios x 2 seeds over a tiny synthetic plate, in parallel.
    let out = run_ok(&[
        "sweep",
        "--seeds",
        "2",
        "--machines",
        "1,2",
        "--wells",
        "2",
        "--sites",
        "1",
        "--job-mean-s",
        "30",
        "--threads",
        "2",
    ]);
    assert!(out.contains("2 scenarios x 2 seeds = 4 cells"), "{out}");
    assert!(out.contains("scenario"), "{out}");
    assert!(out.contains("m=1"), "{out}");
    assert!(out.contains("m=2"), "{out}");
    // Every cell completes its 2 jobs: 8 total across the sweep.
    assert!(out.contains("4/4"), "{out}");
}

#[test]
fn sweep_json_output_parses() {
    let out = run_ok(&[
        "sweep", "--seeds", "2", "--machines", "1", "--wells", "2", "--sites", "1", "--json",
    ]);
    // With --json, stdout is exactly one JSON object (chatter goes to
    // stderr), so the output pipes straight into jq and friends.
    let v = ds_rs::json::parse(out.trim()).unwrap();
    assert_eq!(v.get("total_cells").and_then(ds_rs::json::Value::as_u64), Some(2));
    let scenarios = v.get("scenarios").and_then(ds_rs::json::Value::as_arr).unwrap();
    assert_eq!(scenarios.len(), 1);
}

#[test]
fn allocation_strategy_sweep_reports_per_pool_json() {
    // The acceptance path: a strategy-comparison sweep whose JSON report
    // carries per-pool cost and interruption counts.
    let out = run_ok(&[
        "sweep",
        "--seeds",
        "1",
        "--machines",
        "2",
        "--allocation",
        "lowest-price,diversified,capacity-optimized",
        "--instance-types",
        "m5.large+c5.xlarge",
        "--wells",
        "2",
        "--sites",
        "1",
        "--job-mean-s",
        "30",
        "--json",
    ]);
    let v = ds_rs::json::parse(out.trim()).unwrap();
    let scenarios = v.get("scenarios").and_then(ds_rs::json::Value::as_arr).unwrap();
    assert_eq!(scenarios.len(), 3, "one scenario per strategy");
    for s in scenarios {
        let label = s.get("label").and_then(ds_rs::json::Value::as_str).unwrap();
        assert!(label.contains("alloc="), "{label}");
        let pools = s.get("pools").and_then(ds_rs::json::Value::as_arr).unwrap();
        assert!(!pools.is_empty(), "no pools in {label}");
        for p in pools {
            assert!(p.get("cost_usd").and_then(ds_rs::json::Value::as_f64).is_some());
            assert!(p.get("interrupted").and_then(ds_rs::json::Value::as_u64).is_some());
        }
    }
    // Diversified spread across both pools in its scenario.
    let diversified = scenarios
        .iter()
        .find(|s| {
            s.get("label")
                .and_then(ds_rs::json::Value::as_str)
                .is_some_and(|l| l.contains("alloc=diversified"))
        })
        .unwrap();
    let pools = diversified.get("pools").and_then(ds_rs::json::Value::as_arr).unwrap();
    assert!(pools.len() >= 2, "diversified used one pool: {pools:?}");
}

#[test]
fn describe_reports_per_type_packing() {
    let dir = std::env::temp_dir().join(format!("ds-cli-desc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("config.json");
    run_ok(&["make-config", "--out", cfg.to_str().unwrap()]);
    let out = run_ok(&["describe", "--config", cfg.to_str().unwrap()]);
    assert!(out.contains("placement ("), "{out}");
    assert!(out.contains("m5.xlarge: fits"), "{out}");

    // With --fleet, the Fleet file's INSTANCE_TYPES (the machines the
    // run will actually use) drive the packing table instead.
    let mut fleet = ds_rs::config::FleetSpec::template("us-east-1").unwrap();
    fleet.instance_types = vec![
        ds_rs::aws::ec2::InstanceSlot::new("m5.large"),
        ds_rs::aws::ec2::InstanceSlot {
            name: "c5.xlarge".into(),
            weight: 2,
        },
    ];
    fleet.allocation_strategy = ds_rs::aws::ec2::AllocationStrategy::Diversified;
    let fleet_path = dir.join("fleet.json");
    std::fs::write(&fleet_path, fleet.to_json().pretty()).unwrap();
    let out = run_ok(&[
        "describe",
        "--config",
        cfg.to_str().unwrap(),
        "--fleet",
        fleet_path.to_str().unwrap(),
    ]);
    assert!(out.contains("m5.large: fits"), "{out}");
    assert!(out.contains("c5.xlarge:2: fits"), "{out}");
    assert!(!out.contains("m5.xlarge: fits"), "{out}");
    assert!(out.contains("allocation=diversified"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn data_sweep_json_carries_the_data_breakdown() {
    // The --input-mb / --net-profile axes: jobs gain byte sizes, the
    // JSON report gains per-scenario byte totals, egress dollars, and
    // the bucket-vs-NIC bottleneck attribution.
    let out = run_ok(&[
        "sweep",
        "--seeds",
        "1",
        "--machines",
        "1",
        "--wells",
        "2",
        "--sites",
        "1",
        "--job-mean-s",
        "30",
        "--input-mb",
        "32",
        "--net-profile",
        "narrow",
        "--json",
    ]);
    let v = ds_rs::json::parse(out.trim()).unwrap();
    let scenarios = v.get("scenarios").and_then(ds_rs::json::Value::as_arr).unwrap();
    assert_eq!(scenarios.len(), 1);
    let s = &scenarios[0];
    let label = s.get("label").and_then(ds_rs::json::Value::as_str).unwrap();
    assert!(label.contains("in=32MB") && label.contains("net=narrow"), "{label}");
    let data = s.get("data").unwrap();
    let down = data
        .get("bytes_downloaded")
        .and_then(ds_rs::json::Value::as_u64)
        .unwrap();
    assert!(down > 0, "{data:?}");
    assert!(
        data.get("egress_usd")
            .and_then(ds_rs::json::Value::as_f64)
            .unwrap()
            > 0.0
    );
    assert!(data
        .get("bucket_bound_fraction")
        .and_then(ds_rs::json::Value::as_f64)
        .is_some());
}

#[test]
fn sweep_rejects_bad_net_profile() {
    let out = ds()
        .args(["sweep", "--net-profile", "adsl"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("net-profile"));
}

#[test]
fn describe_prints_job_data_footprint() {
    let dir = std::env::temp_dir().join(format!("ds-cli-foot-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("config.json");
    run_ok(&["make-config", "--out", cfg.to_str().unwrap()]);
    let jobs = ds_rs::config::JobSpec::plate("P1", 2, 2, vec![])
        .with_uniform_data(250_000_000, 25_000_000);
    let job_path = dir.join("job.json");
    std::fs::write(&job_path, jobs.to_json().pretty()).unwrap();
    let out = run_ok(&[
        "describe",
        "--config",
        cfg.to_str().unwrap(),
        "--job",
        job_path.to_str().unwrap(),
    ]);
    assert!(out.contains("job data footprint: 4 groups"), "{out}");
    assert!(out.contains("1.00 GB in / 0.10 GB out"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_rejects_bad_axis_value() {
    let out = ds()
        .args(["sweep", "--machines", "two"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("bad value"));
}

#[test]
fn sweep_rejects_bad_scalar_value() {
    // Scalar flags are strict too: a typo'd --seeds must not silently
    // fall back to the default and run a wrong-sized study.
    let out = ds()
        .args(["sweep", "--seeds", "banana"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("bad value 'banana' for --seeds"));
}

#[test]
fn run_rejects_bad_files() {
    let dir = std::env::temp_dir().join(format!("ds-cli-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("config.json");
    std::fs::write(&cfg, "{\"APP_NAME\": \"x\"}").unwrap();
    let out = ds()
        .args(["describe", "--config", cfg.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("missing field"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn make_fleet_file_unknown_region_fails() {
    let out = ds()
        .args(["make-fleet-file", "--region", "mars-north-1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("no template"));
}

#[test]
fn sweep_dry_run_prints_matrix_without_running() {
    let out = run_ok(&[
        "sweep",
        "--seeds",
        "5",
        "--machines",
        "2,4,8",
        "--volatility",
        "low,high",
        "--wells",
        "2",
        "--sites",
        "1",
        "--dry-run",
    ]);
    assert!(out.contains("dry run"), "{out}");
    // Every axis line shows its Sweep-file key and CLI flag.
    assert!(out.contains("MACHINES"), "{out}");
    assert!(out.contains("(--machines)"), "{out}");
    assert!(out.contains("2, 4, 8"), "{out}");
    // The headline numbers: 6 scenarios x 5 seeds = 30 cells.
    assert!(out.contains("scenarios: 6"), "{out}");
    assert!(out.contains("cells: 30"), "{out}");
    // Nothing ran: no scenario table, no report.
    assert!(!out.contains("makespan"), "{out}");

    // Under --json the dry run stays machine-parseable on stdout.
    let out = run_ok(&[
        "sweep", "--seeds", "5", "--machines", "2,4,8", "--volatility", "low,high",
        "--wells", "2", "--sites", "1", "--dry-run", "--json",
    ]);
    let v = ds_rs::json::parse(out.trim()).unwrap();
    assert_eq!(v.get("scenarios").and_then(ds_rs::json::Value::as_u64), Some(6));
    assert_eq!(v.get("cells").and_then(ds_rs::json::Value::as_u64), Some(30));
    assert!(v.get("axes").and_then(|a| a.get("MACHINES")).is_some());
}

#[test]
fn run_rejects_unknown_and_sweep_only_flags() {
    // `ds run` shares the registry's strictness: a sweep-only axis flag
    // (or a typo) must not silently run a different study.
    let out = ds().args(["run", "--machines", "16"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown flag --machines"), "{err}");
    assert!(err.contains("run --help"), "{err}");
}

#[test]
fn sweep_plan_file_runs_with_cli_overrides() {
    let dir = std::env::temp_dir().join(format!("ds-cli-plan-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let plan_path = dir.join("sweep.json");
    std::fs::write(
        &plan_path,
        r#"{
            "SEEDS": 2,
            "MACHINES": [1, 2],
            "JOB_MEAN_S": [30],
            "WELLS": 2,
            "SITES": 1
        }"#,
    )
    .unwrap();
    // File alone: 2 scenarios x 2 seeds.
    let out = run_ok(&["sweep", "--plan", plan_path.to_str().unwrap(), "--threads", "2"]);
    assert!(out.contains("2 scenarios x 2 seeds = 4 cells"), "{out}");
    assert!(out.contains("m=1"), "{out}");
    assert!(out.contains("m=2"), "{out}");
    // CLI overrides the file's MACHINES axis, keeps its SEEDS.
    let out = run_ok(&[
        "sweep",
        "--plan",
        plan_path.to_str().unwrap(),
        "--machines",
        "4",
        "--threads",
        "2",
    ]);
    assert!(out.contains("1 scenarios x 2 seeds = 2 cells"), "{out}");
    assert!(out.contains("m=4"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_plan_file_rejects_unknown_keys() {
    let dir = std::env::temp_dir().join(format!("ds-cli-plankey-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let plan_path = dir.join("sweep.json");
    std::fs::write(&plan_path, r#"{"MACHNIES": [2]}"#).unwrap();
    let out = ds()
        .args(["sweep", "--plan", plan_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown key 'MACHNIES'"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_rejects_valueless_plan_flag() {
    // `--plan` with a forgotten value must not silently run the default
    // study — same strictness rule as every axis flag.
    let out = ds().args(["sweep", "--plan", "--json"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("missing value for --plan"), "{err}");
}

#[test]
fn sweep_json_carries_registry_axes() {
    // The per-scenario `axes` object: machine-readable coordinates keyed
    // by the registry's Sweep-file keys, so tooling never parses labels.
    let out = run_ok(&[
        "sweep", "--seeds", "1", "--machines", "2", "--input-mb", "8", "--wells", "2",
        "--sites", "1", "--job-mean-s", "30", "--json",
    ]);
    let v = ds_rs::json::parse(out.trim()).unwrap();
    let scenarios = v.get("scenarios").and_then(ds_rs::json::Value::as_arr).unwrap();
    let axes = scenarios[0].get("axes").unwrap();
    assert_eq!(axes.get("MACHINES").and_then(ds_rs::json::Value::as_u64), Some(2));
    assert_eq!(axes.get("INPUT_MB").and_then(ds_rs::json::Value::as_f64), Some(8.0));
    assert_eq!(axes.get("VOLATILITY").and_then(ds_rs::json::Value::as_str), Some("low"));
    // Unused optional axes stay out, mirroring the label rule.
    assert!(axes.get("NET_PROFILE").is_none());
}

#[test]
fn workloads_lists_artifacts_when_built() {
    let art = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(art).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = run_ok(&["workloads", "--artifacts", art]);
    assert!(out.contains("cp_256_b1"));
    assert!(out.contains("Pyramid"));
}
