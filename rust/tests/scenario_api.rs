//! Scenario API v2 gates: the axis registry is the single source of
//! truth, and every front door (builder, Sweep file, CLI flags) builds
//! the same plan.
//!
//! Two properties matter:
//!
//! 1. **Round-trip**: builder → `SweepFile::render` → parse → the same
//!    plan, down to a bit-identical `SweepReport` when executed.
//! 2. **Consistency**: the set of registered flags == the flags in the
//!    generated help == the keys a Sweep file accepts; nothing else
//!    defines the sweep surface.

use ds_rs::aws::ec2::{AllocationStrategy, InstanceSlot, Volatility};
use ds_rs::aws::s3::dataplane::NetProfile;
use ds_rs::config::JobSpec;
use ds_rs::coordinator::autoscale::ScalingMode;
use ds_rs::coordinator::sweep::{run_sweep, Scenario, SweepPlan};
use ds_rs::scenario::{
    plan_from_cli, render_flag_specs, run_flags, sweep_flags, Axis, SweepFile, AXES,
};
use ds_rs::sim::{SimRng, MINUTE};
use ds_rs::testutil::fixtures::args as cli;
use ds_rs::testutil::forall_r;
use ds_rs::topology::{ClusterTopology, Placement};
use ds_rs::traffic::{QueueingPolicy, TrafficSpec};
use ds_rs::workloads::DurationModel;

/// A random small-but-varied plan touching every axis with some
/// probability.  Kept tiny so the executed round-trip cases stay fast.
fn random_plan(rng: &mut SimRng) -> SweepPlan {
    let mut b = SweepPlan::builder()
        .jobs(JobSpec::plate("P", 2, 1, vec![]))
        .seeds((0..rng.range_u64(1, 3)).map(|i| rng.below(50) + i));
    if rng.chance(0.7) {
        b = b.machines((0..rng.range_u64(1, 3)).map(|_| rng.range_u64(1, 3) as u32));
    }
    if rng.chance(0.5) {
        b = b.visibilities((0..rng.range_u64(1, 3)).map(|_| rng.range_u64(1, 12) * MINUTE));
    }
    if rng.chance(0.5) {
        b = b.volatilities(vec![*rng.pick(&[
            Volatility::Low,
            Volatility::Medium,
            Volatility::High,
        ])]);
    }
    if rng.chance(0.5) {
        b = b.allocations(vec![*rng.pick(&AllocationStrategy::ALL)]);
    }
    if rng.chance(0.4) {
        let sets = vec![
            Vec::new(),
            vec![
                InstanceSlot::new("m5.large"),
                InstanceSlot {
                    name: "c5.xlarge".into(),
                    weight: rng.range_u64(1, 3) as u32,
                },
            ],
        ];
        b = b.instance_sets(sets);
    }
    if rng.chance(0.4) {
        b = b.input_mbs(vec![0.0, rng.range_u64(1, 8) as f64]);
    }
    if rng.chance(0.4) {
        b = b.net_profiles(vec![rng.pick(&NetProfile::ALL).clone()]);
    }
    if rng.chance(0.4) {
        b = b.scalings(vec![ScalingMode::None, *rng.pick(&[
            ScalingMode::TargetTracking,
            ScalingMode::Step,
        ])]);
    }
    if rng.chance(0.4) {
        b = b.scaling_targets(vec![1.0 + rng.below(8) as f64]);
    }
    if rng.chance(0.6) {
        b = b.models(vec![DurationModel {
            mean_s: rng.range_u64(10, 40) as f64,
            cv: 0.2,
            stall_prob: 0.0,
            fail_prob: 0.0,
        }]);
    }
    if rng.chance(0.3) {
        // An inline (non-shape) topology exercises the TOPOLOGY axis's
        // object rendering through the file.
        let topo = if rng.chance(0.5) {
            ClusterTopology::shape(*rng.pick(&["three-az", "two-region"]))
        } else {
            Some(
                ClusterTopology::builder("inline")
                    .domain("az-a", "r1")
                    .domain("az-b", "r2")
                    .fault(
                        ds_rs::topology::FaultKind::AzOutage,
                        "az-a",
                        rng.below(30),
                        rng.range_u64(5, 60),
                        1.0,
                    )
                    .build()
                    .expect("inline topology"),
            )
        };
        b = b.topologies(vec![None, topo]);
    }
    if rng.chance(0.3) {
        b = b.placements(vec![Placement::Pack, *rng.pick(&[
            Placement::Spread,
            Placement::Cheapest,
        ])]);
    }
    if rng.chance(0.3) {
        // An inline (non-shape) traffic spec exercises the TRAFFIC
        // axis's object rendering through the file.
        let spec = if rng.chance(0.5) {
            TrafficSpec::shape(*rng.pick(&["two-tenant", "noisy-neighbor"]))
        } else {
            Some(
                TrafficSpec::builder("inline")
                    .tenant("a", rng.range_u64(2, 6), 1, 0, 600)
                    .tenant("b", rng.range_u64(2, 6), 2, 1, 120)
                    .poisson("a", 1.0 + rng.below(3) as f64)
                    .diurnal("b", 0.5, 2.0, rng.range_u64(30, 120))
                    .build()
                    .expect("inline traffic"),
            )
        };
        b = b.traffics(vec![None, spec]);
    }
    if rng.chance(0.3) {
        b = b.queueings(vec![QueueingPolicy::Fifo, *rng.pick(&[
            QueueingPolicy::FairShare,
            QueueingPolicy::Priority,
        ])]);
    }
    b.build().expect("builder plan")
}

fn labels(plan: &SweepPlan) -> Vec<String> {
    plan.matrix.scenarios().iter().map(Scenario::label).collect()
}

#[test]
fn prop_builder_renders_and_parses_to_the_same_plan() {
    forall_r(
        "sweep-file-round-trip",
        40,
        0x5EED,
        |rng| {
            let plan = random_plan(rng);
            let text = SweepFile::render(&plan);
            (plan, text)
        },
        |(plan, text)| {
            let back = SweepFile::from_text(text)
                .map_err(|e| format!("render did not parse: {e:#}"))?
                .to_plan()
                .map_err(|e| format!("parsed file did not plan: {e:#}"))?;
            if back.base_cfg != plan.base_cfg {
                return Err("config drifted through the file".into());
            }
            if back.jobs != plan.jobs {
                return Err("jobs drifted through the file".into());
            }
            if back.fleet != plan.fleet {
                return Err("fleet drifted through the file".into());
            }
            if back.matrix.seeds != plan.matrix.seeds {
                return Err(format!(
                    "seeds drifted: {:?} vs {:?}",
                    plan.matrix.seeds, back.matrix.seeds
                ));
            }
            if labels(&back) != labels(plan) {
                return Err(format!(
                    "scenario labels drifted:\n  {:?}\nvs\n  {:?}",
                    labels(plan),
                    labels(&back)
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn round_tripped_plan_executes_bit_identically() {
    // The expensive half of the property, on a few fixed cases: the
    // re-parsed plan's SweepReport is bit-identical to the original's,
    // at more than one thread count.
    for seed in [1u64, 7, 23] {
        let mut rng = SimRng::new(seed);
        let plan = random_plan(&mut rng);
        let back = SweepFile::from_text(&SweepFile::render(&plan))
            .unwrap()
            .to_plan()
            .unwrap();
        let a = run_sweep(&plan, 2).unwrap();
        let b = run_sweep(&back, 2).unwrap();
        assert_eq!(a.report, b.report, "case seed {seed}");
        assert_eq!(a.cells, b.cells, "case seed {seed}");
        let b1 = run_sweep(&back, 1).unwrap();
        assert_eq!(a.report, b1.report, "case seed {seed} (1 thread)");
    }
}

#[test]
fn registered_flags_equal_help_equal_file_keys() {
    let flags = sweep_flags();
    // Every axis registers at least one flag carrying its file key.
    for ax in AXES {
        let spec = ax.flags()[0];
        assert!(
            flags.iter().any(|f| f.flag == spec.flag),
            "axis {} missing from sweep_flags()",
            ax.key()
        );
        assert_eq!(
            spec.file_key,
            Some(ax.key()),
            "axis {} primary flag must carry its file key",
            ax.key()
        );
    }
    // The generated help documents exactly the registered flags.
    let help = render_flag_specs(&flags);
    for f in &flags {
        assert!(
            help.contains(&format!("--{}", f.flag)),
            "--{} missing from generated help",
            f.flag
        );
    }
    // Every declared file key is accepted by the Sweep-file parser: a
    // known key may fail on its *value*, but never as an unknown key.
    for f in &flags {
        let Some(key) = f.file_key else { continue };
        let text = format!("{{\"{key}\": {{}}}}");
        if let Err(e) = SweepFile::from_text(&text).and_then(|f| f.to_plan()) {
            let msg = format!("{e:#}");
            assert!(
                !msg.contains("unknown key"),
                "registered key {key} rejected as unknown: {msg}"
            );
        }
    }
    // And nothing outside the registry is accepted.
    let err = SweepFile::from_text(r#"{"NOT_AN_AXIS": 1}"#).unwrap_err();
    assert!(format!("{err:#}").contains("unknown key"), "{err:#}");
}

#[test]
fn run_flags_are_the_registry_subset_plus_run_only() {
    let run = run_flags();
    let sweep = sweep_flags();
    // The shared axes appear in both tables with identical spelling.
    for shared in [
        "volatility", "job-mean-s", "job-cv", "stall-prob", "fail-prob", "input-mb",
        "net-profile", "scaling", "scaling-target",
    ] {
        assert!(run.iter().any(|f| f.flag == shared), "run missing --{shared}");
        assert!(sweep.iter().any(|f| f.flag == shared), "sweep missing --{shared}");
    }
    // Fleet-shaping axes stay sweep-only: a single run reads them from
    // its Config/Fleet files.
    for sweep_only in ["machines", "visibility-s", "allocation", "instance-types"] {
        assert!(
            !run.iter().any(|f| f.flag == sweep_only),
            "--{sweep_only} must not leak into ds run"
        );
    }
}

#[test]
fn cli_overrides_beat_file_keys_beat_defaults() {
    let file = SweepFile::from_text(
        r#"{"MACHINES": [2, 4], "VOLATILITY": ["high"], "SEEDS": 3, "WELLS": 2, "SITES": 1}"#,
    )
    .unwrap();
    let plan = plan_from_cli(&cli("sweep --machines 8 --input-mb 16"), Some(&file)).unwrap();
    // CLI wins where both spoke.
    assert_eq!(plan.matrix.cluster_machines, vec![8]);
    // File wins where only it spoke.
    assert_eq!(plan.matrix.volatilities, vec![Volatility::High]);
    assert_eq!(plan.matrix.seeds, vec![0, 1, 2]);
    // CLI-only axes apply on top of the file.
    assert_eq!(plan.matrix.input_mbs, vec![16.0]);
    // Defaults fill the rest.
    assert_eq!(plan.matrix.allocations, vec![AllocationStrategy::LowestPrice]);
}

#[test]
fn cli_only_plan_matches_the_legacy_flag_surface() {
    // The exact invocation shape PR 2/PR 3 documented, now resolved
    // through the registry: same matrix, same labels.
    let plan = plan_from_cli(
        &cli(
            "sweep --seeds 2 --machines 2,4 --visibility-s 120,600 --volatility low,medium \
             --allocation lowest-price,diversified --instance-types m5.large+c5.xlarge:2 \
             --input-mb 0,64 --net-profile standard,narrow --job-mean-s 90,240 --wells 2 --sites 1",
        ),
        None,
    )
    .unwrap();
    let scs = plan.matrix.scenarios();
    assert_eq!(scs.len(), 2 * 2 * 2 * 2 * 1 * 2 * 2 * 2);
    assert_eq!(plan.matrix.cell_count(), scs.len() * 2);
    assert_eq!(
        scs[0].label(),
        "m=2 vis=2.0m vol=low mean=90s alloc=lowest-price set=m5.large+c5.xlarge:2"
    );
    let last = scs.last().unwrap();
    assert_eq!(
        last.label(),
        "m=4 vis=10.0m vol=medium mean=240s alloc=diversified set=m5.large+c5.xlarge:2 in=64MB net=narrow"
    );
}
