//! Control-loop invariants for the elastic autoscaler (DESIGN.md §8):
//!
//! * capacity never exits `[min_capacity, max_capacity]`, for any
//!   policy, backlog sequence, or signal order;
//! * applied scale-outs are never closer together than the scale-out
//!   cooldown (ditto scale-ins);
//! * target-tracking converges on steady arrivals: the backlog per
//!   unit ends inside the policy band instead of diverging;
//! * scale-in never strands work: a job whose machine is terminated
//!   mid-flight redelivers through its SQS visibility lease and still
//!   completes — elasticity cannot lose jobs;
//! * the `--scaling` axes round-trip through a Sweep file into a
//!   bit-identical report (the `ds sweep --scaling … --json`
//!   acceptance path).

use ds_rs::config::JobSpec;
use ds_rs::coordinator::autoscale::{ScalingMode, ScalingPolicy};
use ds_rs::coordinator::run::{run_full, RunOptions, Simulation};
use ds_rs::coordinator::sweep::{run_sweep, SweepPlan};
use ds_rs::scenario::SweepFile;
use ds_rs::sim::MINUTE;
use ds_rs::testutil::fixtures::{modeled, plate_jobs, quick_cfg, shaped, template_fleet};
use ds_rs::testutil::forall_r;

/// Random policy with random (ordered) bounds.
fn random_policy(rng: &mut ds_rs::sim::SimRng) -> ScalingPolicy {
    let target = 0.5 + rng.f64() * 8.0;
    let mut p = if rng.chance(0.5) {
        ScalingPolicy::target_tracking(target)
    } else {
        ScalingPolicy::step(target)
    };
    let a = 1 + rng.below(12) as u32;
    let b = 1 + rng.below(12) as u32;
    p.limits.min_capacity = a.min(b);
    p.limits.max_capacity = a.max(b);
    p
}

#[test]
fn prop_desired_capacity_never_exits_bounds() {
    forall_r(
        "autoscale-bounds",
        120,
        0x5CA1E,
        |rng| {
            let p = random_policy(rng);
            let current = rng.below(20) as u32;
            let backlog = rng.below(10_000);
            (p, current, backlog)
        },
        |(p, current, backlog)| {
            let (lo, hi) = (p.limits.min_capacity, p.limits.max_capacity);
            let out = p.desired_out(*current, *backlog);
            let inn = p.desired_in(*current, *backlog);
            if !(lo..=hi).contains(&out) {
                return Err(format!("desired_out {out} outside [{lo}, {hi}]"));
            }
            if !(lo..=hi).contains(&inn) {
                return Err(format!("desired_in {inn} outside [{lo}, {hi}]"));
            }
            // Directionality: out never shrinks below a bounded current,
            // in never grows above it.
            if (lo..=hi).contains(current) {
                if out < *current {
                    return Err(format!("scale-out shrank: {current} -> {out}"));
                }
                if inn > *current {
                    return Err(format!("scale-in grew: {current} -> {inn}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_desired_out_monotone_in_backlog() {
    // More backlog never asks for less capacity (both policies).
    forall_r(
        "autoscale-monotone",
        80,
        0xB4C0,
        |rng| {
            let p = random_policy(rng);
            let current = 1 + rng.below(10) as u32;
            let b1 = rng.below(2_000);
            let b2 = b1 + rng.below(2_000);
            (p, current, b1, b2)
        },
        |(p, current, b1, b2)| {
            let d1 = p.desired_out(*current, *b1);
            let d2 = p.desired_out(*current, *b2);
            if d2 < d1 {
                return Err(format!(
                    "backlog {b1}->{b2} lowered desired {d1}->{d2} ({:?})",
                    p.kind
                ));
            }
            Ok(())
        },
    );
}

/// Run one elastic simulation and return its report.
fn elastic_run(
    policy: ScalingPolicy,
    waves: &[(u64, u32)],
    mean_s: f64,
    seed: u64,
) -> ds_rs::metrics::RunReport {
    let cfg = quick_cfg(4); // 4 machines = 16 workers at full size
    let opts = RunOptions {
        seed,
        scaling: Some(policy),
        ..Default::default()
    };
    let mut sim = Simulation::new(cfg, opts).unwrap();
    let (first, rest) = waves.split_first().expect("at least one wave");
    sim.submit(&JobSpec::plate("P1", first.1, 1, vec![])).unwrap();
    for &(at_min, jobs) in rest {
        sim.submit_at(at_min * MINUTE, JobSpec::plate("P1", jobs, 1, vec![]));
    }
    sim.start(&template_fleet()).unwrap();
    let mut ex = modeled(mean_s);
    sim.run(&mut ex).unwrap()
}

#[test]
fn capacity_timeline_respects_bounds_and_cooldowns() {
    for mode in [ScalingMode::TargetTracking, ScalingMode::Step] {
        let mut policy = mode.policy(2.0).unwrap();
        policy.limits.scale_in_cooldown = 4 * MINUTE;
        policy.limits.scale_out_cooldown = 3 * MINUTE;
        policy.limits.warmup = 4 * MINUTE;
        let limits = policy.limits.clone();
        // Three bursts with idle gaps: plenty of in and out decisions.
        let report = elastic_run(policy, &[(0, 24), (45, 24), (90, 24)], 180.0, 7);
        assert!(report.fully_accounted(), "{}", report.summary());
        assert!(
            report.scaling.scale_ins >= 1 && report.scaling.scale_outs >= 1,
            "loop never exercised both directions: {:?}",
            report.scaling
        );
        let tl = &report.scaling.timeline;
        let mut last_out: Option<u64> = None;
        let mut last_in: Option<u64> = None;
        for d in tl {
            assert!(
                (1..=4).contains(&d.to),
                "capacity {} exits [1, 4] at {} ({mode:?})",
                d.to,
                d.at
            );
            if d.to > d.from {
                if let Some(prev) = last_out {
                    assert!(
                        d.at - prev >= limits.scale_out_cooldown,
                        "scale-outs {prev} and {} inside the cooldown ({mode:?})",
                        d.at
                    );
                }
                last_out = Some(d.at);
            } else {
                if let Some(prev) = last_in {
                    assert!(
                        d.at - prev >= limits.scale_in_cooldown,
                        "scale-ins {prev} and {} inside the cooldown ({mode:?})",
                        d.at
                    );
                }
                last_in = Some(d.at);
            }
        }
        // The breakdown's counters agree with its own timeline.
        assert_eq!(report.scaling.decisions as usize, tl.len());
        assert_eq!(
            report.scaling.scale_outs as usize,
            tl.iter().filter(|d| d.to > d.from).count()
        );
    }
}

#[test]
fn target_tracking_converges_on_steady_arrivals() {
    // Steady load: 4 jobs/minute at 120 s mean on 2-core containers —
    // about 8 compute-busy workers, i.e. ~2 machines of the 4 allowed.
    // After two hours of arrivals the controller must have settled: the
    // backlog per unit ends within the policy band (not diverging, not
    // collapsed to the floor with a runaway queue).
    let mut policy = ScalingPolicy::target_tracking(4.0);
    policy.limits.scale_in_cooldown = 3 * MINUTE;
    policy.limits.warmup = 3 * MINUTE;
    let target = policy.target_per_unit;
    let cfg = quick_cfg(4);
    let opts = RunOptions {
        seed: 11,
        scaling: Some(policy),
        // Cut the run at the end of the arrival phase: we inspect the
        // steady state, not the final drain.
        max_sim_time: 120 * MINUTE,
        ..Default::default()
    };
    let mut sim = Simulation::new(cfg, opts).unwrap();
    sim.submit(&JobSpec::plate("P1", 4, 1, vec![])).unwrap();
    for k in 1..120u64 {
        sim.submit_at(k * MINUTE, JobSpec::plate("P1", 4, 1, vec![]));
    }
    sim.start(&template_fleet()).unwrap();
    let mut ex = modeled(120.0);
    let report = sim.run(&mut ex).unwrap();
    // Steady state at cutoff: look at the live queue and fleet.
    let (visible, in_flight) = sim
        .acct
        .sqs
        .approximate_counts("MyApp-queue", 120 * MINUTE);
    let backlog = (visible + in_flight) as f64;
    let capacity = f64::from(sim.acct.ec2.fleet_target(1).max(1));
    let per_unit = backlog / capacity;
    assert!(
        per_unit <= 3.0 * target,
        "diverged: backlog/unit {per_unit:.1} vs target {target} ({})",
        report.summary()
    );
    assert!(
        backlog < 200.0,
        "runaway queue: {backlog} jobs pending after 2 h of steady load"
    );
    // The controller actually worked (made decisions) and the loop kept
    // completing jobs at the arrival rate.
    assert!(report.scaling.decisions >= 1, "{:?}", report.scaling);
    assert!(
        report.stats.completed >= 400,
        "throughput fell behind steady arrivals: {}",
        report.summary()
    );
}

#[test]
fn scale_in_never_strands_in_flight_work() {
    // An aggressive scale-in policy (tight band, short cooldowns) that
    // terminates machines running jobs: every terminated job's message
    // redelivers via its visibility lease and the run still accounts
    // for every submitted job, across failure-heavy executors.
    forall_r(
        "autoscale-no-strand",
        6,
        0xA5CA,
        |rng| {
            let seed = rng.next_u64();
            let target = 1.0 + rng.f64() * 4.0;
            let mean_s = 120.0 + rng.f64() * 240.0;
            let step = rng.chance(0.5);
            (seed, target, mean_s, step)
        },
        |&(seed, target, mean_s, step)| {
            let mut policy = if step {
                ScalingPolicy::step(target)
            } else {
                ScalingPolicy::target_tracking(target)
            };
            policy.limits.scale_in_cooldown = MINUTE;
            policy.limits.warmup = MINUTE;
            let cfg = quick_cfg(4);
            let jobs = plate_jobs(10, 2); // 20 jobs
            let opts = RunOptions {
                seed,
                scaling: Some(policy),
                ..Default::default()
            };
            let mut ex = shaped(mean_s, 0.4, 0.0, 0.05);
            let report = run_full(&cfg, &jobs, &template_fleet(), &mut ex, opts)
                .map_err(|e| e.to_string())?;
            if !report.fully_accounted() {
                return Err(format!("stranded work: {}", report.summary()));
            }
            if !report.cleaned_up {
                return Err(format!("no cleanup: {}", report.summary()));
            }
            Ok(())
        },
    );
}

#[test]
fn scaling_sweep_round_trips_through_a_sweep_file_bit_identically() {
    // The acceptance path: `ds sweep --scaling … --json` rendered to a
    // Sweep file, re-parsed, re-run — bit-identical report.
    let plan = SweepPlan::builder()
        .config(quick_cfg(3))
        .jobs(plate_jobs(8, 2))
        .seeds([1, 2])
        .scalings([ScalingMode::None, ScalingMode::TargetTracking, ScalingMode::Step])
        .scaling_targets([2.0])
        .job_mean_s([240.0])
        .build()
        .unwrap();
    let text = SweepFile::render(&plan);
    let back = SweepFile::from_text(&text).unwrap().to_plan().unwrap();
    let a = run_sweep(&plan, 2).unwrap();
    let b = run_sweep(&back, 2).unwrap();
    assert_eq!(a.report, b.report);
    assert_eq!(a.cells, b.cells);
    // Labels distinguish the policies, and only when engaged.
    let labels: Vec<String> = a.report.scenarios.iter().map(|s| s.label.clone()).collect();
    assert!(!labels[0].contains("scale="), "{labels:?}");
    assert!(labels[1].contains("scale=target-tracking tgt=2"), "{labels:?}");
    assert!(labels[2].contains("scale=step tgt=2"), "{labels:?}");
}
