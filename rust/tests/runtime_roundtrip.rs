//! End-to-end AOT bridge test: load every artifact through PJRT and check
//! numerics against pure-Rust oracles / golden values from the python
//! side (python/tests/test_aot.py::TestNumericGroundTruth).
//!
//! Requires `make artifacts` (skips politely otherwise).

use ds_rs::runtime::{PjrtRuntime, WorkloadKind};
use ds_rs::workloads::synth::SynthImage;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(dir)
        .join("manifest.json")
        .exists()
        .then(|| dir.to_string())
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_lists_all_seven_workloads() {
    let dir = require_artifacts!();
    let rt = PjrtRuntime::new(&dir).unwrap();
    let names = rt.manifest().names();
    for expected in [
        "cp_128_b1",
        "cp_256_b1",
        "cp_256_b4",
        "stitch_g2_t128_o16",
        "stitch_g3_t128_o16",
        "pyramid_256_l4",
        "pyramid_512_l5",
    ] {
        assert!(names.contains(&expected), "missing {expected} in {names:?}");
    }
}

#[test]
fn pyramid_golden_numerics() {
    // Mirrors python/tests/test_aot.py::test_pyramid_ramp_golden: a ramp
    // image through the AOT pyramid must keep exact structure.
    let dir = require_artifacts!();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let n = 256 * 256;
    let img: Vec<f32> = (0..n).map(|i| i as f32 / n as f32).collect();
    let (out, ms) = rt.execute("pyramid_256_l4", &[img.clone()]).unwrap();
    assert!(ms > 0.0);
    // Level 0 is the input verbatim.
    assert_eq!(&out[..n], &img[..]);
    // Every level preserves the global mean (average pooling).
    let mean0: f64 = img.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    let mut off = n;
    for size in [128usize, 64, 32] {
        let lvl = &out[off..off + size * size];
        let m: f64 = lvl.iter().map(|&v| v as f64).sum::<f64>() / lvl.len() as f64;
        assert!(
            (m - mean0).abs() < 1e-4,
            "level {size}: mean {m} vs {mean0}"
        );
        off += size * size;
    }
    assert_eq!(off, out.len());
}

#[test]
fn pyramid_level1_is_2x2_mean() {
    let dir = require_artifacts!();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let img = SynthImage {
        size: 256,
        ..Default::default()
    }
    .render(7);
    let (out, _) = rt.execute("pyramid_256_l4", &[img.clone()]).unwrap();
    let l1 = &out[256 * 256..256 * 256 + 128 * 128];
    // Check a handful of positions against a direct 2x2 mean.
    for &(y, x) in &[(0usize, 0usize), (10, 50), (63, 127), (127, 0)] {
        let expect = (img[(2 * y) * 256 + 2 * x]
            + img[(2 * y) * 256 + 2 * x + 1]
            + img[(2 * y + 1) * 256 + 2 * x]
            + img[(2 * y + 1) * 256 + 2 * x + 1])
            / 4.0;
        let got = l1[y * 128 + x];
        assert!(
            (got - expect).abs() < 1e-5,
            "level1[{y},{x}] = {got}, want {expect}"
        );
    }
}

#[test]
fn cellprofiler_features_sane_on_synthetic_field() {
    let dir = require_artifacts!();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let img = SynthImage {
        size: 256,
        n_blobs: 24,
        ..Default::default()
    }
    .render(42);
    let (out, _) = rt.execute("cp_256_b1", &[img]).unwrap();
    assert_eq!(out.len(), 16);
    let feat = |i: usize| out[i];
    let (fg_mean, fg_frac, bg_mean) = (feat(0), feat(2), feat(5));
    assert!(out.iter().all(|v| v.is_finite()), "{out:?}");
    assert!(
        fg_mean > bg_mean,
        "foreground should be brighter: fg={fg_mean} bg={bg_mean}"
    );
    assert!(
        fg_frac > 0.0 && fg_frac < 0.6,
        "plausible foreground fraction: {fg_frac}"
    );
}

#[test]
fn cellprofiler_batch4_matches_four_singles() {
    let dir = require_artifacts!();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let gen = SynthImage {
        size: 256,
        ..Default::default()
    };
    let imgs: Vec<Vec<f32>> = (0..4).map(|i| gen.render(100 + i)).collect();
    let mut batched_input = Vec::new();
    for img in &imgs {
        batched_input.extend_from_slice(img);
    }
    let (batched, _) = rt.execute("cp_256_b4", &[batched_input]).unwrap();
    for (i, img) in imgs.iter().enumerate() {
        let (single, _) = rt.execute("cp_256_b1", &[img.clone()]).unwrap();
        let row = &batched[i * 16..(i + 1) * 16];
        for (a, b) in row.iter().zip(&single) {
            assert!(
                (a - b).abs() < 1e-3 * b.abs().max(1.0),
                "batch row {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn stitch_montage_and_scores() {
    let dir = require_artifacts!();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let gen = SynthImage {
        size: 128,
        noise_sd: 0.002,
        ..Default::default()
    };
    let tiles = gen.render_tiles(11, 2, 128, 16);
    let mut input = Vec::new();
    for t in &tiles {
        input.extend_from_slice(t);
    }
    let (out, _) = rt.execute("stitch_g2_t128_o16", &[input]).unwrap();
    let side = 2 * 128 - 16;
    assert_eq!(out.len(), side * side + 4);
    let scores = &out[side * side..];
    // Tiles cut from one field: seams must correlate strongly.
    for (i, s) in scores.iter().enumerate() {
        assert!(*s > 0.8, "seam {i} NCC too low: {s}");
    }
    // Montage pixel range sane.
    let montage = &out[..side * side];
    assert!(montage.iter().all(|v| v.is_finite() && *v >= 0.0 && *v <= 2.5));
}

#[test]
fn executable_cache_compiles_once() {
    let dir = require_artifacts!();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let img = SynthImage {
        size: 128,
        ..Default::default()
    }
    .render(1);
    let _ = rt.execute("cp_128_b1", &[img.clone()]).unwrap();
    let (compile_ms_1, n1, _) = rt.stats("cp_128_b1").unwrap();
    let _ = rt.execute("cp_128_b1", &[img]).unwrap();
    let (compile_ms_2, n2, _) = rt.stats("cp_128_b1").unwrap();
    assert_eq!(compile_ms_1, compile_ms_2, "no recompilation");
    assert_eq!(n2, n1 + 1);
    assert!(rt.mean_latency_ms("cp_128_b1").unwrap() > 0.0);
}

#[test]
fn wrong_input_shape_is_rejected() {
    let dir = require_artifacts!();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let err = rt
        .execute("cp_128_b1", &[vec![0.0; 10]])
        .unwrap_err()
        .to_string();
    assert!(err.contains("expected"), "{err}");
    assert!(rt.execute("cp_128_b1", &[]).is_err());
}
