//! Integration: the paper's four-command flow over the full account sim
//! (Figure 1 / experiment F1), with modeled job durations.

use ds_rs::aws::ec2::Volatility;
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, RunOptions, Simulation};
use ds_rs::sim::{HOUR, MINUTE};
use ds_rs::workloads::{DurationModel, ModeledExecutor};

fn cfg(machines: u32) -> AppConfig {
    AppConfig {
        app_name: "NuclearSegmentation_Drosophila".into(),
        cluster_machines: machines,
        tasks_per_machine: 2,
        docker_cores: 2,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: 10 * MINUTE,
        sqs_queue_name: "nucseg-queue".into(),
        sqs_dead_letter_queue: "nucseg-dlq".into(),
        log_group_name: "nucseg".into(),
        ..Default::default()
    }
}

fn executor(mean_s: f64) -> ModeledExecutor {
    ModeledExecutor {
        model: DurationModel {
            mean_s,
            cv: 0.3,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn fleet_file() -> FleetSpec {
    FleetSpec::template("us-east-1").unwrap()
}

#[test]
fn figure1_full_plate_run() {
    // 96-well plate, 4 sites: 384 jobs over 8 machines (32 worker cores).
    let cfg = cfg(8);
    let jobs = JobSpec::plate("BR00117010", 96, 4, vec![]);
    let mut ex = executor(90.0);
    let report = run_full(&cfg, &jobs, &fleet_file(), &mut ex, RunOptions::default()).unwrap();

    assert_eq!(report.jobs_submitted, 384);
    assert_eq!(report.stats.completed, 384, "{}", report.summary());
    assert!(report.cleaned_up, "monitor must tear everything down");
    assert_eq!(report.stats.dead_lettered, 0);
    // 384 jobs * 90 s / 32 cores ≈ 18 min of work; makespan under 2 h
    // even with boot time and tail effects.
    let makespan = report.makespan().unwrap();
    assert!(makespan < 2 * HOUR, "makespan {makespan}");
    assert!(makespan > 10 * MINUTE);
    // Spot is a real discount.
    assert!(report.cost.spot_savings_factor() > 2.0);
    // Coordinator overhead is negligible vs compute (paper's claim).
    assert!(
        report.cost.overhead_fraction() < 0.10,
        "overhead {}",
        report.cost.overhead_fraction()
    );
}

#[test]
fn all_five_services_touched() {
    let cfg = cfg(2);
    let jobs = JobSpec::plate("P", 4, 2, vec![]);
    let mut sim = Simulation::new(cfg.clone(), RunOptions::default()).unwrap();
    sim.submit(&jobs).unwrap();
    sim.start(&fleet_file()).unwrap();
    let mut ex = executor(30.0);
    let report = sim.run(&mut ex).unwrap();
    assert_eq!(report.stats.completed, 8);

    // S3: outputs + exported logs present.
    assert!(!sim.acct.s3.list_prefix("ds-data", "output/").is_empty());
    assert!(!sim.acct.s3.list_prefix("ds-data", "exportedlogs/").is_empty());
    // SQS: queue deleted by cleanup, DLQ still there and empty.
    assert!(!sim.acct.sqs.queue_exists(&cfg.sqs_queue_name));
    assert_eq!(
        sim.acct
            .sqs
            .approximate_counts(&cfg.sqs_dead_letter_queue, report.ended_at),
        (0, 0)
    );
    // EC2: every instance terminated, at least 2 launched.
    assert!(report.stats.instances_launched >= 2);
    assert!(sim.acct.ec2.all_instances().iter().all(|i| !i.is_active()));
    // ECS: fully clean.
    assert!(sim.acct.ecs.is_clean(&cfg.service_name(), &cfg.task_family()));
    // CloudWatch: metrics were published, alarms all deleted.
    assert!(sim.acct.metrics.put_count() > 0);
    assert!(sim.acct.alarms.is_empty());
}

#[test]
fn seconds_to_start_staggers_but_completes() {
    let mut c = cfg(2);
    c.seconds_to_start = 30_000; // 30 s between core launches
    let jobs = JobSpec::plate("P", 6, 2, vec![]);
    let mut ex = executor(45.0);
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, RunOptions::default()).unwrap();
    assert_eq!(report.stats.completed, 12, "{}", report.summary());
}

#[test]
fn non_default_cluster_works_end_to_end() {
    // The paper's NuclearSegmentation_Drosophila vs _HeLa isolation story
    // rests on distinct ECS clusters; verify a non-default cluster works.
    let mut c = cfg(2);
    c.ecs_cluster = "drosophila".into();
    let jobs = JobSpec::plate("P", 4, 1, vec![]);
    let mut ex = executor(20.0);
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, RunOptions::default()).unwrap();
    assert_eq!(report.stats.completed, 4);
}

#[test]
fn resume_after_interrupted_run_skips_done_work() {
    // Experiment T6: first run killed at ~50%, resubmit with
    // CHECK_IF_DONE on; only the unfinished half reruns.
    let c = cfg(4);
    let jobs = JobSpec::plate("P", 24, 2, vec![]); // 48 jobs
    let opts1 = RunOptions {
        max_sim_time: 6 * MINUTE,
        ..Default::default()
    };
    let mut sim1 = Simulation::new(c.clone(), opts1).unwrap();
    sim1.submit(&jobs).unwrap();
    sim1.start(&fleet_file()).unwrap();
    let mut ex = executor(120.0);
    let r1 = sim1.run(&mut ex).unwrap();
    assert!(
        r1.stats.completed > 0 && r1.stats.completed < 48,
        "{}",
        r1.summary()
    );
    // Carry the outputs into a fresh account (same S3 contents) and rerun.
    let done_keys: Vec<(String, u64)> = sim1.acct.s3.list_prefix("ds-data", "output/");
    let mut sim2 = Simulation::new(c.clone(), RunOptions::default()).unwrap();
    sim2.stage(|acct| {
        for (k, sz) in &done_keys {
            acct.s3
                .put("ds-data", k, ds_rs::aws::s3::Body::Synthetic { size: *sz }, 0)
                .unwrap();
        }
    });
    sim2.submit(&jobs).unwrap();
    sim2.start(&fleet_file()).unwrap();
    let mut ex2 = executor(120.0);
    let r2 = sim2.run(&mut ex2).unwrap();
    assert_eq!(
        r2.stats.completed + r2.stats.skipped_done,
        48,
        "{}",
        r2.summary()
    );
    assert_eq!(r2.stats.skipped_done, r1.stats.completed);
    assert!(r2.stats.completed < 48);
}

#[test]
fn large_machine_single_task_stitching_shape() {
    // "a large machine to perform a single task on many images (such as
    // stitching)": one m5.12xlarge, one fat container.
    let c = AppConfig {
        app_name: "Stitch".into(),
        cluster_machines: 1,
        tasks_per_machine: 1,
        docker_cores: 1,
        machine_types: vec!["m5.12xlarge".into()],
        machine_price: 1.00,
        cpu_shares: 48 * 1024,
        memory_mb: 180_000,
        sqs_queue_name: "stitch-q".into(),
        sqs_dead_letter_queue: "stitch-dlq".into(),
        ..Default::default()
    };
    let jobs = JobSpec::plate("Montage", 3, 1, vec![]);
    let mut ex = executor(300.0);
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, RunOptions::default()).unwrap();
    assert_eq!(report.stats.completed, 3, "{}", report.summary());
    assert!(report.cleaned_up);
}

#[test]
fn medium_volatility_still_completes() {
    let c = cfg(4);
    let jobs = JobSpec::plate("P", 24, 2, vec![]);
    let opts = RunOptions {
        volatility: Volatility::Medium,
        seed: 7,
        ..Default::default()
    };
    let mut ex = executor(120.0);
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, opts).unwrap();
    assert!(report.fully_accounted(), "{}", report.summary());
    assert_eq!(report.stats.dead_lettered, 0);
}

#[test]
fn cheapest_mode_cheaper_but_not_faster() {
    let c = cfg(6);
    let jobs = JobSpec::plate("P", 48, 4, vec![]); // 192 jobs
    let run_mode = |cheapest: bool| {
        let mut ex = executor(120.0);
        run_full(
            &c,
            &jobs,
            &fleet_file(),
            &mut ex,
            RunOptions {
                cheapest,
                seed: 11,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let normal = run_mode(false);
    let cheap = run_mode(true);
    assert_eq!(normal.stats.completed, 192, "{}", normal.summary());
    assert_eq!(cheap.stats.completed, 192, "{}", cheap.summary());
    // Cheapest mode must never beat normal on makespan (no replacement).
    assert!(cheap.makespan().unwrap() >= normal.makespan().unwrap());
}
