//! Property-based invariants over the substrates and the whole run
//! (DESIGN.md §6), using the in-house forall harness.

use ds_rs::aws::ec2::{SpotMarket, Volatility};
use ds_rs::aws::sqs::{RedrivePolicy, Sqs};
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::json;
use ds_rs::sim::{EventQueue, SimRng, HOUR, MINUTE};
use ds_rs::testutil::{forall, forall_r};
use ds_rs::workloads::{DurationModel, ModeledExecutor};

#[test]
fn prop_sqs_conservation() {
    // Under any interleaving of send/receive/delete/expiry, every message
    // is exactly one of: visible, in-flight, deleted, or dead-lettered.
    forall_r(
        "sqs-conservation",
        60,
        0xABCD,
        |rng| {
            // op stream: (kind, arg) pairs
            let n_ops = 200;
            let ops: Vec<(u8, u64)> = (0..n_ops)
                .map(|_| (rng.below(4) as u8, rng.next_u64()))
                .collect();
            ops
        },
        |ops| {
            let mut sqs = Sqs::new();
            sqs.create_queue("q", 2 * MINUTE);
            sqs.create_queue("dlq", 2 * MINUTE);
            sqs.set_redrive("q", "dlq", RedrivePolicy { max_receive_count: 3 })
                .unwrap();
            let mut now = 0u64;
            let mut sent = 0u64;
            let mut deleted = 0u64;
            let mut handles: Vec<u64> = Vec::new();
            for (kind, arg) in ops {
                now += arg % (3 * MINUTE);
                match kind {
                    0 => {
                        sqs.send("q", format!("m{sent}"), now).unwrap();
                        sent += 1;
                    }
                    1 => {
                        if let Some((_, h)) = sqs.receive("q", now).unwrap() {
                            handles.push(h);
                        }
                    }
                    2 => {
                        if !handles.is_empty() {
                            let h = handles.remove((arg % handles.len() as u64) as usize);
                            if sqs.delete("q", h, now).is_ok() {
                                deleted += 1;
                            }
                        }
                    }
                    _ => {
                        // pure time passage
                    }
                }
            }
            // Settle all visibility timeouts.
            now += HOUR;
            let (vis, inflight) = sqs.approximate_counts("q", now);
            let (dlq_vis, dlq_inflight) = sqs.approximate_counts("dlq", now);
            if inflight != 0 {
                return Err(format!("in-flight after settle: {inflight}"));
            }
            let accounted = vis as u64 + deleted + dlq_vis as u64 + dlq_inflight as u64;
            if accounted != sent {
                return Err(format!(
                    "lost/duplicated messages: sent={sent} accounted={accounted} \
                     (vis={vis} deleted={deleted} dlq={dlq_vis})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_queue_monotone_fifo() {
    forall(
        "event-queue-monotone",
        50,
        0xEEE,
        |rng| {
            let n = 300;
            (0..n).map(|_| rng.below(10_000)).collect::<Vec<u64>>()
        },
        |times| {
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.schedule_at(t, i);
            }
            let mut last: Option<(u64, usize)> = None;
            while let Some((t, i)) = q.pop() {
                if let Some((lt, li)) = last {
                    if t < lt {
                        return false;
                    }
                    // FIFO within a timestamp: indices increase.
                    if t == lt && i < li {
                        return false;
                    }
                }
                last = Some((t, i));
            }
            true
        },
    );
}

#[test]
fn prop_market_cost_integral_consistent() {
    // Integral over [a,c) == [a,b) + [b,c) for arbitrary split points,
    // across volatilities and seeds.
    forall_r(
        "market-integral-additive",
        40,
        0x7777,
        |rng| {
            let seed = rng.next_u64();
            let a = rng.below(48 * HOUR);
            let b = a + rng.below(12 * HOUR);
            let c = b + rng.below(12 * HOUR);
            let vol = match rng.below(3) {
                0 => Volatility::Low,
                1 => Volatility::Medium,
                _ => Volatility::High,
            };
            (seed, a, b, c, vol)
        },
        |&(seed, a, b, c, vol)| {
            let mut m = SpotMarket::new(seed, vol);
            let whole = m.cost_integral("m5.xlarge", a, c);
            let parts =
                m.cost_integral("m5.xlarge", a, b) + m.cost_integral("m5.xlarge", b, c);
            if (whole - parts).abs() > 1e-9 * whole.abs().max(1.0) {
                return Err(format!("whole={whole} parts={parts}"));
            }
            if whole < 0.0 {
                return Err("negative cost".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    // Arbitrary generated values survive pretty -> parse.
    fn gen_value(rng: &mut SimRng, depth: u32) -> json::Value {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.chance(0.5)),
            2 => {
                // Round-trippable finite numbers.
                json::Value::Num((rng.next_u64() % 1_000_000) as f64 / 64.0)
            }
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\\'
                        }
                    })
                    .collect();
                json::Value::Str(s)
            }
            4 => {
                let n = rng.below(5);
                json::Value::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.below(5);
                json::Value::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                        .collect(),
                )
            }
        }
    }
    forall(
        "json-roundtrip",
        100,
        0x15AC,
        |rng| gen_value(rng, 0),
        |v| json::parse(&v.pretty()).map(|p| p == *v).unwrap_or(false),
    );
}

#[test]
fn prop_every_job_accounted_across_configs() {
    // The big one: for random (machines, tasks, cores, visibility, mean
    // duration, stall/fail rates, volatility) configurations, every
    // submitted job ends completed, skipped, or dead-lettered, and the
    // monitor always cleans up within the time cap.
    forall_r(
        "run-accounting",
        12,
        0xC0FFEE,
        |rng| {
            let machines = 1 + rng.below(6) as u32;
            let tasks = 1 + rng.below(3) as u32;
            let cores = 1 + rng.below(3) as u32;
            let vis_min = 2 + rng.below(10);
            let mean_s = 20.0 + rng.f64() * 160.0;
            let stall = if rng.chance(0.3) { 0.05 } else { 0.0 };
            let fail = if rng.chance(0.3) { 0.10 } else { 0.0 };
            let jobs = 8 + rng.below(40);
            let seed = rng.next_u64();
            (machines, tasks, cores, vis_min, mean_s, stall, fail, jobs, seed)
        },
        |&(machines, tasks, cores, vis_min, mean_s, stall, fail, jobs_n, seed)| {
            let cfg = AppConfig {
                cluster_machines: machines,
                tasks_per_machine: tasks,
                docker_cores: cores,
                machine_types: vec!["m5.xlarge".into()],
                machine_price: 0.10,
                sqs_message_visibility: vis_min * MINUTE,
                ..Default::default()
            };
            let jobs = JobSpec::plate("P", jobs_n as u32, 1, vec![]);
            let fleet = FleetSpec::template("us-east-1").unwrap();
            let mut ex = ModeledExecutor {
                model: DurationModel {
                    mean_s,
                    cv: 0.4,
                    stall_prob: stall,
                    fail_prob: fail,
                },
                ..Default::default()
            };
            let opts = RunOptions {
                seed,
                max_sim_time: 3 * 24 * HOUR,
                ..Default::default()
            };
            let report = run_full(&cfg, &jobs, &fleet, &mut ex, opts)
                .map_err(|e| e.to_string())?;
            if !report.fully_accounted() {
                return Err(format!("jobs unaccounted: {}", report.summary()));
            }
            if !report.cleaned_up {
                return Err(format!("no cleanup: {}", report.summary()));
            }
            if report.cost.total_usd() <= 0.0 {
                return Err("zero cost for a real run".into());
            }
            Ok(())
        },
    );
}
