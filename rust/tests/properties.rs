//! Property-based invariants over the substrates and the whole run
//! (DESIGN.md §6), using the in-house forall harness.

use ds_rs::aws::billing::CostReport;
use ds_rs::aws::ec2::{
    AllocationStrategy, Ec2, FleetEvent, InstanceSlot, SpotFleetSpec, SpotMarket, Volatility,
};
use ds_rs::aws::sqs::{RedrivePolicy, Sqs};
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::json;
use ds_rs::metrics::{Aggregate, RunReport, RunStats, ScenarioSummary};
use ds_rs::sim::{EventQueue, SimRng, HOUR, MINUTE};
use ds_rs::testutil::{forall, forall_r};
use ds_rs::workloads::{DurationModel, ModeledExecutor};

#[test]
fn prop_sqs_conservation() {
    // Under any interleaving of send/receive/delete/expiry, every message
    // is exactly one of: visible, in-flight, deleted, or dead-lettered.
    forall_r(
        "sqs-conservation",
        60,
        0xABCD,
        |rng| {
            // op stream: (kind, arg) pairs
            let n_ops = 200;
            let ops: Vec<(u8, u64)> = (0..n_ops)
                .map(|_| (rng.below(4) as u8, rng.next_u64()))
                .collect();
            ops
        },
        |ops| {
            let mut sqs = Sqs::new();
            sqs.create_queue("q", 2 * MINUTE);
            sqs.create_queue("dlq", 2 * MINUTE);
            sqs.set_redrive("q", "dlq", RedrivePolicy { max_receive_count: 3 })
                .unwrap();
            let mut now = 0u64;
            let mut sent = 0u64;
            let mut deleted = 0u64;
            let mut handles: Vec<u64> = Vec::new();
            for (kind, arg) in ops {
                now += arg % (3 * MINUTE);
                match kind {
                    0 => {
                        sqs.send("q", format!("m{sent}"), now).unwrap();
                        sent += 1;
                    }
                    1 => {
                        if let Some((_, h)) = sqs.receive("q", now).unwrap() {
                            handles.push(h);
                        }
                    }
                    2 => {
                        if !handles.is_empty() {
                            let h = handles.remove((arg % handles.len() as u64) as usize);
                            if sqs.delete("q", h, now).is_ok() {
                                deleted += 1;
                            }
                        }
                    }
                    _ => {
                        // pure time passage
                    }
                }
            }
            // Settle all visibility timeouts.
            now += HOUR;
            let (vis, inflight) = sqs.approximate_counts("q", now);
            let (dlq_vis, dlq_inflight) = sqs.approximate_counts("dlq", now);
            if inflight != 0 {
                return Err(format!("in-flight after settle: {inflight}"));
            }
            let accounted = vis as u64 + deleted + dlq_vis as u64 + dlq_inflight as u64;
            if accounted != sent {
                return Err(format!(
                    "lost/duplicated messages: sent={sent} accounted={accounted} \
                     (vis={vis} deleted={deleted} dlq={dlq_vis})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_queue_monotone_fifo() {
    forall(
        "event-queue-monotone",
        50,
        0xEEE,
        |rng| {
            let n = 300;
            (0..n).map(|_| rng.below(10_000)).collect::<Vec<u64>>()
        },
        |times| {
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.schedule_at(t, i);
            }
            let mut last: Option<(u64, usize)> = None;
            while let Some((t, i)) = q.pop() {
                if let Some((lt, li)) = last {
                    if t < lt {
                        return false;
                    }
                    // FIFO within a timestamp: indices increase.
                    if t == lt && i < li {
                        return false;
                    }
                }
                last = Some((t, i));
            }
            true
        },
    );
}

#[test]
fn prop_market_cost_integral_consistent() {
    // Integral over [a,c) == [a,b) + [b,c) for arbitrary split points,
    // across volatilities and seeds.
    forall_r(
        "market-integral-additive",
        40,
        0x7777,
        |rng| {
            let seed = rng.next_u64();
            let a = rng.below(48 * HOUR);
            let b = a + rng.below(12 * HOUR);
            let c = b + rng.below(12 * HOUR);
            let vol = match rng.below(3) {
                0 => Volatility::Low,
                1 => Volatility::Medium,
                _ => Volatility::High,
            };
            (seed, a, b, c, vol)
        },
        |&(seed, a, b, c, vol)| {
            let mut m = SpotMarket::new(seed, vol);
            let whole = m.cost_integral("m5.xlarge", a, c);
            let parts =
                m.cost_integral("m5.xlarge", a, b) + m.cost_integral("m5.xlarge", b, c);
            if (whole - parts).abs() > 1e-9 * whole.abs().max(1.0) {
                return Err(format!("whole={whole} parts={parts}"));
            }
            if whole < 0.0 {
                return Err("negative cost".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    // Arbitrary generated values survive pretty -> parse.
    fn gen_value(rng: &mut SimRng, depth: u32) -> json::Value {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.chance(0.5)),
            2 => {
                // Round-trippable finite numbers.
                json::Value::Num((rng.next_u64() % 1_000_000) as f64 / 64.0)
            }
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\\'
                        }
                    })
                    .collect();
                json::Value::Str(s)
            }
            4 => {
                let n = rng.below(5);
                json::Value::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.below(5);
                json::Value::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                        .collect(),
                )
            }
        }
    }
    forall(
        "json-roundtrip",
        100,
        0x15AC,
        |rng| gen_value(rng, 0),
        |v| json::parse(&v.pretty()).map(|p| p == *v).unwrap_or(false),
    );
}

#[test]
fn prop_every_job_accounted_across_configs() {
    // The big one: for random (machines, tasks, cores, visibility, mean
    // duration, stall/fail rates, instance set, allocation strategy,
    // on-demand base) configurations, every submitted job ends completed,
    // skipped, or dead-lettered; the monitor always cleans up within the
    // time cap; and the per-pool breakdown conserves the EC2 bill.
    const TYPE_POOL: &[&str] = &["m5.large", "m5.xlarge", "c5.xlarge", "r5.xlarge"];
    forall_r(
        "run-accounting",
        12,
        0xC0FFEE,
        |rng| {
            let machines = 1 + rng.below(6) as u32;
            let tasks = 1 + rng.below(3) as u32;
            let cores = 1 + rng.below(3) as u32;
            let vis_min = 2 + rng.below(10);
            let mean_s = 20.0 + rng.f64() * 160.0;
            let stall = if rng.chance(0.3) { 0.05 } else { 0.0 };
            let fail = if rng.chance(0.3) { 0.10 } else { 0.0 };
            let jobs = 8 + rng.below(40);
            let n_types = 1 + rng.below(TYPE_POOL.len() as u64) as usize;
            let first_type = rng.below(TYPE_POOL.len() as u64) as usize;
            let alloc = AllocationStrategy::ALL[rng.below(3) as usize];
            let od_base = rng.below(2) as u32; // 0 or 1, always <= machines
            let seed = rng.next_u64();
            (
                (machines, tasks, cores, vis_min, mean_s, stall, fail, jobs, seed),
                (n_types, first_type, alloc, od_base),
            )
        },
        |&(
            (machines, tasks, cores, vis_min, mean_s, stall, fail, jobs_n, seed),
            (n_types, first_type, alloc, od_base),
        )| {
            let cfg = AppConfig {
                cluster_machines: machines,
                tasks_per_machine: tasks,
                docker_cores: cores,
                machine_types: vec!["m5.xlarge".into()],
                // Generous per-unit bid so every chosen pool is usable.
                machine_price: 0.30,
                sqs_message_visibility: vis_min * MINUTE,
                ..Default::default()
            };
            let jobs = JobSpec::plate("P", jobs_n as u32, 1, vec![]);
            let mut fleet = FleetSpec::template("us-east-1").unwrap();
            fleet.instance_types = (0..n_types)
                .map(|i| InstanceSlot::new(TYPE_POOL[(first_type + i) % TYPE_POOL.len()]))
                .collect();
            fleet.allocation_strategy = alloc;
            fleet.on_demand_base = od_base;
            let mut ex = ModeledExecutor {
                model: DurationModel {
                    mean_s,
                    cv: 0.4,
                    stall_prob: stall,
                    fail_prob: fail,
                },
                ..Default::default()
            };
            let opts = RunOptions {
                seed,
                max_sim_time: 3 * 24 * HOUR,
                ..Default::default()
            };
            let report = run_full(&cfg, &jobs, &fleet, &mut ex, opts)
                .map_err(|e| e.to_string())?;
            if !report.fully_accounted() {
                return Err(format!("jobs unaccounted: {}", report.summary()));
            }
            if !report.cleaned_up {
                return Err(format!("no cleanup: {}", report.summary()));
            }
            if report.cost.total_usd() <= 0.0 {
                return Err("zero cost for a real run".into());
            }
            // Pool conservation: the per-pool slices sum to the EC2 bill.
            let pool_cost: f64 = report.pools.iter().map(|p| p.cost_usd).sum();
            if (pool_cost - report.cost.ec2_usd).abs() > 1e-9 * report.cost.ec2_usd.max(1.0) {
                return Err(format!(
                    "pool breakdown leaks: pools={pool_cost} ec2={}",
                    report.cost.ec2_usd
                ));
            }
            let launched: u64 = report.pools.iter().map(|p| p.launched).sum();
            if launched != report.stats.instances_launched {
                return Err(format!(
                    "pool launch counts drifted: {launched} != {}",
                    report.stats.instances_launched
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Allocation-strategy invariants (DESIGN.md §2: heterogeneous fleets)
// ---------------------------------------------------------------------------

const ALLOC_TYPES: &[&str] = &["m5.large", "m5.xlarge", "c5.xlarge", "r5.xlarge", "c5.2xlarge"];

/// Launch `target` weight-1 units with `alloc` on a fresh market and
/// return (per-type launch counts, sum of launch-event prices).
fn fulfill(
    seed: u64,
    types: &[&str],
    alloc: AllocationStrategy,
    target: u32,
) -> (Vec<(String, u32)>, f64) {
    let mut ec2 = Ec2::new(SpotMarket::new(seed, Volatility::Low), SimRng::new(seed ^ 0xF1EE7));
    let fid = ec2.request_spot_fleet(SpotFleetSpec {
        target_capacity: target,
        bid_hourly: 1.0, // generous: every pool eligible in a quiet market
        slots: types.iter().map(|t| InstanceSlot::new(*t)).collect(),
        allocation: alloc,
        on_demand_base: 0,
    });
    let evs = ec2.evaluate_fleets(0);
    let mut price_sum = 0.0;
    for ev in &evs {
        if let FleetEvent::InstanceRequested { price, .. } = ev {
            price_sum += price;
        }
    }
    assert_eq!(ec2.active_weight(fid), target, "generous bid must fulfill");
    let counts = types
        .iter()
        .map(|t| {
            let n = ec2
                .all_instances()
                .iter()
                .filter(|i| i.itype.name == *t)
                .count() as u32;
            (t.to_string(), n)
        })
        .collect();
    (counts, price_sum)
}

#[test]
fn prop_diversified_spreads_capacity_evenly() {
    // With every pool eligible and deep enough, Diversified's per-pool
    // counts differ by at most one and sum to the target.
    forall_r(
        "diversified-spreads",
        40,
        0xD1F,
        |rng| {
            let k = 2 + rng.below(ALLOC_TYPES.len() as u64 - 1) as usize;
            let target = 1 + rng.below(60) as u32;
            let seed = rng.next_u64();
            (seed, k, target)
        },
        |&(seed, k, target)| {
            let types = &ALLOC_TYPES[..k];
            let (counts, _) = fulfill(seed, types, AllocationStrategy::Diversified, target);
            let total: u32 = counts.iter().map(|(_, n)| n).sum();
            if total != target {
                return Err(format!("total {total} != target {target}"));
            }
            let max = counts.iter().map(|(_, n)| *n).max().unwrap();
            let min = counts.iter().map(|(_, n)| *n).min().unwrap();
            if max - min > 1 {
                return Err(format!("uneven spread: {counts:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lowest_price_never_pays_more_at_fulfillment() {
    // In a quiet market (no spike between the strategies' identical
    // evaluations), LowestPrice's total launch price is <= any other
    // strategy's for the same request.
    forall_r(
        "lowest-price-is-lowest",
        40,
        0x10E5,
        |rng| {
            let k = 2 + rng.below(ALLOC_TYPES.len() as u64 - 1) as usize;
            let target = 1 + rng.below(40) as u32;
            let seed = rng.next_u64();
            (seed, k, target)
        },
        |&(seed, k, target)| {
            let types = &ALLOC_TYPES[..k];
            let (_, lowest) = fulfill(seed, types, AllocationStrategy::LowestPrice, target);
            for alloc in [
                AllocationStrategy::Diversified,
                AllocationStrategy::CapacityOptimized,
            ] {
                let (_, other) = fulfill(seed, types, alloc, target);
                if lowest > other + 1e-9 {
                    return Err(format!(
                        "lowest-price paid more: {lowest} > {other} ({})",
                        alloc.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fulfilled_weight_matches_request() {
    // With weighted slots and a generous bid, fulfilled weighted capacity
    // reaches the target and overshoots by less than the largest weight.
    forall_r(
        "weighted-fulfillment",
        40,
        0x3E16,
        |rng| {
            let k = 1 + rng.below(3) as usize;
            let weights: Vec<u32> = (0..k).map(|_| 1 + rng.below(4) as u32).collect();
            let target = 1 + rng.below(50) as u32;
            let alloc = AllocationStrategy::ALL[rng.below(3) as usize];
            let seed = rng.next_u64();
            (seed, weights, target, alloc)
        },
        |(seed, weights, target, alloc)| {
            let slots: Vec<InstanceSlot> = weights
                .iter()
                .enumerate()
                .map(|(i, &w)| InstanceSlot {
                    name: ALLOC_TYPES[i].to_string(),
                    weight: w,
                })
                .collect();
            let max_w = *weights.iter().max().unwrap();
            let mut ec2 =
                Ec2::new(SpotMarket::new(*seed, Volatility::Low), SimRng::new(seed ^ 0xBEEF));
            let fid = ec2.request_spot_fleet(SpotFleetSpec {
                target_capacity: *target,
                bid_hourly: 1.0,
                slots,
                allocation: *alloc,
                on_demand_base: 0,
            });
            ec2.evaluate_fleets(0);
            let got = ec2.active_weight(fid);
            if got < *target {
                return Err(format!("underfilled: {got} < {target}"));
            }
            if got >= *target + max_w {
                return Err(format!("overshot by a full slot: {got} >= {target}+{max_w}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Sweep-aggregation invariants (DESIGN.md §5/§6)
// ---------------------------------------------------------------------------

/// Random synthetic RunReport: non-negative counters and cost, sometimes
/// drained, sometimes not.
fn gen_report(rng: &mut SimRng) -> RunReport {
    let submitted = 1 + rng.below(500);
    let completed = rng.below(submitted + 1);
    let dead_lettered = rng.below(submitted - completed + 1);
    let drained_at = rng.chance(0.8).then(|| 1 + rng.below(48 * HOUR));
    let machine_hours = rng.f64() * 100.0;
    RunReport {
        stats: RunStats {
            completed,
            skipped_done: rng.below(50),
            duplicates: rng.below(20),
            dead_lettered,
            instances_launched: rng.below(64),
            interruptions: rng.below(16),
            lost_to_death: rng.below(8),
            ..Default::default()
        },
        drained_at,
        ended_at: drained_at.unwrap_or(0) + rng.below(12 * HOUR),
        cleaned_up: rng.chance(0.9),
        cost: CostReport {
            ec2_usd: machine_hours * 0.03,
            sqs_usd: rng.f64() * 0.01,
            s3_usd: rng.f64() * 0.01,
            s3_egress_usd: rng.f64() * 0.01,
            cloudwatch_usd: rng.f64() * 0.01,
            machine_hours,
            on_demand_equivalent_usd: machine_hours * 0.096,
        },
        pools: vec![ds_rs::metrics::PoolBreakdown {
            pool: "m5.xlarge".into(),
            launched: rng.below(64),
            interrupted: rng.below(16),
            machine_hours,
            cost_usd: machine_hours * 0.03,
        }],
        data: ds_rs::metrics::DataBreakdown {
            bytes_downloaded: rng.below(1u64 << 32),
            bytes_uploaded: rng.below(1u64 << 30),
            bytes_wasted: rng.below(1u64 << 24),
            egress_usd: rng.f64() * 0.1,
            bucket_bound_ms: rng.below(1u64 << 20),
            nic_bound_ms: rng.below(1u64 << 20),
            ..Default::default()
        },
        scaling: ds_rs::metrics::ScalingBreakdown {
            policy: "target-tracking".into(),
            decisions: rng.below(16),
            scale_outs: rng.below(8),
            scale_ins: rng.below(8),
            units_launched: rng.below(64),
            units_terminated: rng.below(64),
            peak_capacity: rng.below(32) as u32,
            floor_capacity: 1 + rng.below(4) as u32,
            capacity_unit_hours: rng.f64() * 50.0,
            ..Default::default()
        },
        jobs_submitted: submitted,
    }
}

#[test]
fn prop_aggregate_order_statistics() {
    // For any sample: n matches, min <= p50 <= p95 <= max, mean within
    // [min, max], and the summary is permutation-invariant bit-for-bit.
    forall_r(
        "aggregate-order-statistics",
        80,
        0xA66,
        |rng| {
            let n = rng.below(40) as usize;
            (0..n).map(|_| rng.lognormal_mean_cv(100.0, 1.0)).collect::<Vec<f64>>()
        },
        |xs| {
            let a = Aggregate::from_values(xs);
            if a.n != xs.len() {
                return Err(format!("n={} len={}", a.n, xs.len()));
            }
            if xs.is_empty() {
                return (a == Aggregate::from_values(&[]))
                    .then_some(())
                    .ok_or_else(|| "empty aggregate not canonical".into());
            }
            if !(a.min <= a.p50 && a.p50 <= a.p95 && a.p95 <= a.max) {
                return Err(format!("order violated: {a:?}"));
            }
            if !(a.min <= a.mean && a.mean <= a.max) {
                return Err(format!("mean outside range: {a:?}"));
            }
            let mut rev = xs.clone();
            rev.reverse();
            if Aggregate::from_values(&rev) != a {
                return Err("not permutation-invariant".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scenario_summary_conserves_totals() {
    // Aggregate job totals equal the sum of per-cell totals, rates stay
    // in [0, 1], cost is non-negative, and p50 <= p95 on every aggregate.
    forall_r(
        "scenario-summary-totals",
        60,
        0x5CE,
        |rng| {
            let n = 1 + rng.below(8) as usize;
            (0..n).map(|_| gen_report(rng)).collect::<Vec<RunReport>>()
        },
        |reports| {
            let refs: Vec<&RunReport> = reports.iter().collect();
            let s = ScenarioSummary::from_reports("p", &refs);
            let sum = |f: fn(&RunReport) -> u64| -> u64 { reports.iter().map(f).sum() };
            if s.jobs_submitted != sum(|r| r.jobs_submitted)
                || s.completed != sum(|r| r.stats.completed)
                || s.skipped_done != sum(|r| r.stats.skipped_done)
                || s.dead_lettered != sum(|r| r.stats.dead_lettered)
                || s.duplicates != sum(|r| r.stats.duplicates)
                || s.instances_launched != sum(|r| r.stats.instances_launched)
                || s.interruptions != sum(|r| r.stats.interruptions)
            {
                return Err(format!("summed counters drifted: {s:?}"));
            }
            if s.scaling.decisions != sum(|r| r.scaling.decisions)
                || s.scaling.units_launched != sum(|r| r.scaling.units_launched)
                || s.scaling.units_terminated != sum(|r| r.scaling.units_terminated)
            {
                return Err(format!("scaling counters drifted: {:?}", s.scaling));
            }
            if reports.iter().any(|r| r.scaling.peak_capacity > s.scaling.peak_capacity) {
                return Err("scaling peak is not the max over cells".into());
            }
            if s.cells != reports.len() {
                return Err(format!("cells={} != {}", s.cells, reports.len()));
            }
            if s.drained != reports.iter().filter(|r| r.drained_at.is_some()).count() {
                return Err("drained count wrong".into());
            }
            if s.makespan_s.n != s.drained || s.jobs_per_hour.n != s.drained {
                return Err("drained-only aggregates cover wrong sample".into());
            }
            for (name, a) in [
                ("makespan", &s.makespan_s),
                ("jobs/h", &s.jobs_per_hour),
                ("cost", &s.cost_usd),
                ("dup-rate", &s.duplicate_rate),
                ("dlq-rate", &s.dead_letter_rate),
            ] {
                if a.p50 > a.p95 {
                    return Err(format!("{name}: p50 > p95: {a:?}"));
                }
                if a.min < 0.0 {
                    return Err(format!("{name}: negative: {a:?}"));
                }
            }
            if s.duplicate_rate.max > 1.0 || s.dead_letter_rate.max > 1.0 {
                return Err("rate above 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cost_nonnegative_and_monotone_in_billed_hours() {
    // Scaling every cell's billed machine-hours (at a fixed hourly rate)
    // by lambda >= 1 never decreases any cost aggregate; cost is never
    // negative.
    forall_r(
        "cost-monotone-in-hours",
        60,
        0xC057,
        |rng| {
            let n = 1 + rng.below(6) as usize;
            let reports: Vec<RunReport> = (0..n).map(|_| gen_report(rng)).collect();
            let lambda = 1.0 + rng.f64() * 4.0;
            (reports, lambda)
        },
        |(reports, lambda)| {
            let refs: Vec<&RunReport> = reports.iter().collect();
            let base = ScenarioSummary::from_reports("c", &refs);
            let scaled_reports: Vec<RunReport> = reports
                .iter()
                .map(|r| {
                    let mut r = r.clone();
                    r.cost.machine_hours *= lambda;
                    r.cost.ec2_usd *= lambda; // same $/hour, more hours
                    r
                })
                .collect();
            let scaled_refs: Vec<&RunReport> = scaled_reports.iter().collect();
            let scaled = ScenarioSummary::from_reports("c", &scaled_refs);
            if base.cost_usd.min < 0.0 {
                return Err(format!("negative cost: {:?}", base.cost_usd));
            }
            for (name, b, s) in [
                ("mean", base.cost_usd.mean, scaled.cost_usd.mean),
                ("p50", base.cost_usd.p50, scaled.cost_usd.p50),
                ("p95", base.cost_usd.p95, scaled.cost_usd.p95),
                ("max", base.cost_usd.max, scaled.cost_usd.max),
            ] {
                if s < b {
                    return Err(format!(
                        "cost {name} decreased with more billed hours: {b} -> {s} (lambda={lambda})"
                    ));
                }
            }
            Ok(())
        },
    );
}
