//! Property-based invariants over the substrates and the whole run
//! (DESIGN.md §6), using the in-house forall harness.

use ds_rs::aws::billing::CostReport;
use ds_rs::aws::ec2::{SpotMarket, Volatility};
use ds_rs::aws::sqs::{RedrivePolicy, Sqs};
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::json;
use ds_rs::metrics::{Aggregate, RunReport, RunStats, ScenarioSummary};
use ds_rs::sim::{EventQueue, SimRng, HOUR, MINUTE};
use ds_rs::testutil::{forall, forall_r};
use ds_rs::workloads::{DurationModel, ModeledExecutor};

#[test]
fn prop_sqs_conservation() {
    // Under any interleaving of send/receive/delete/expiry, every message
    // is exactly one of: visible, in-flight, deleted, or dead-lettered.
    forall_r(
        "sqs-conservation",
        60,
        0xABCD,
        |rng| {
            // op stream: (kind, arg) pairs
            let n_ops = 200;
            let ops: Vec<(u8, u64)> = (0..n_ops)
                .map(|_| (rng.below(4) as u8, rng.next_u64()))
                .collect();
            ops
        },
        |ops| {
            let mut sqs = Sqs::new();
            sqs.create_queue("q", 2 * MINUTE);
            sqs.create_queue("dlq", 2 * MINUTE);
            sqs.set_redrive("q", "dlq", RedrivePolicy { max_receive_count: 3 })
                .unwrap();
            let mut now = 0u64;
            let mut sent = 0u64;
            let mut deleted = 0u64;
            let mut handles: Vec<u64> = Vec::new();
            for (kind, arg) in ops {
                now += arg % (3 * MINUTE);
                match kind {
                    0 => {
                        sqs.send("q", format!("m{sent}"), now).unwrap();
                        sent += 1;
                    }
                    1 => {
                        if let Some((_, h)) = sqs.receive("q", now).unwrap() {
                            handles.push(h);
                        }
                    }
                    2 => {
                        if !handles.is_empty() {
                            let h = handles.remove((arg % handles.len() as u64) as usize);
                            if sqs.delete("q", h, now).is_ok() {
                                deleted += 1;
                            }
                        }
                    }
                    _ => {
                        // pure time passage
                    }
                }
            }
            // Settle all visibility timeouts.
            now += HOUR;
            let (vis, inflight) = sqs.approximate_counts("q", now);
            let (dlq_vis, dlq_inflight) = sqs.approximate_counts("dlq", now);
            if inflight != 0 {
                return Err(format!("in-flight after settle: {inflight}"));
            }
            let accounted = vis as u64 + deleted + dlq_vis as u64 + dlq_inflight as u64;
            if accounted != sent {
                return Err(format!(
                    "lost/duplicated messages: sent={sent} accounted={accounted} \
                     (vis={vis} deleted={deleted} dlq={dlq_vis})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_queue_monotone_fifo() {
    forall(
        "event-queue-monotone",
        50,
        0xEEE,
        |rng| {
            let n = 300;
            (0..n).map(|_| rng.below(10_000)).collect::<Vec<u64>>()
        },
        |times| {
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.schedule_at(t, i);
            }
            let mut last: Option<(u64, usize)> = None;
            while let Some((t, i)) = q.pop() {
                if let Some((lt, li)) = last {
                    if t < lt {
                        return false;
                    }
                    // FIFO within a timestamp: indices increase.
                    if t == lt && i < li {
                        return false;
                    }
                }
                last = Some((t, i));
            }
            true
        },
    );
}

#[test]
fn prop_market_cost_integral_consistent() {
    // Integral over [a,c) == [a,b) + [b,c) for arbitrary split points,
    // across volatilities and seeds.
    forall_r(
        "market-integral-additive",
        40,
        0x7777,
        |rng| {
            let seed = rng.next_u64();
            let a = rng.below(48 * HOUR);
            let b = a + rng.below(12 * HOUR);
            let c = b + rng.below(12 * HOUR);
            let vol = match rng.below(3) {
                0 => Volatility::Low,
                1 => Volatility::Medium,
                _ => Volatility::High,
            };
            (seed, a, b, c, vol)
        },
        |&(seed, a, b, c, vol)| {
            let mut m = SpotMarket::new(seed, vol);
            let whole = m.cost_integral("m5.xlarge", a, c);
            let parts =
                m.cost_integral("m5.xlarge", a, b) + m.cost_integral("m5.xlarge", b, c);
            if (whole - parts).abs() > 1e-9 * whole.abs().max(1.0) {
                return Err(format!("whole={whole} parts={parts}"));
            }
            if whole < 0.0 {
                return Err("negative cost".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    // Arbitrary generated values survive pretty -> parse.
    fn gen_value(rng: &mut SimRng, depth: u32) -> json::Value {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.chance(0.5)),
            2 => {
                // Round-trippable finite numbers.
                json::Value::Num((rng.next_u64() % 1_000_000) as f64 / 64.0)
            }
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\\'
                        }
                    })
                    .collect();
                json::Value::Str(s)
            }
            4 => {
                let n = rng.below(5);
                json::Value::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.below(5);
                json::Value::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                        .collect(),
                )
            }
        }
    }
    forall(
        "json-roundtrip",
        100,
        0x15AC,
        |rng| gen_value(rng, 0),
        |v| json::parse(&v.pretty()).map(|p| p == *v).unwrap_or(false),
    );
}

#[test]
fn prop_every_job_accounted_across_configs() {
    // The big one: for random (machines, tasks, cores, visibility, mean
    // duration, stall/fail rates, volatility) configurations, every
    // submitted job ends completed, skipped, or dead-lettered, and the
    // monitor always cleans up within the time cap.
    forall_r(
        "run-accounting",
        12,
        0xC0FFEE,
        |rng| {
            let machines = 1 + rng.below(6) as u32;
            let tasks = 1 + rng.below(3) as u32;
            let cores = 1 + rng.below(3) as u32;
            let vis_min = 2 + rng.below(10);
            let mean_s = 20.0 + rng.f64() * 160.0;
            let stall = if rng.chance(0.3) { 0.05 } else { 0.0 };
            let fail = if rng.chance(0.3) { 0.10 } else { 0.0 };
            let jobs = 8 + rng.below(40);
            let seed = rng.next_u64();
            (machines, tasks, cores, vis_min, mean_s, stall, fail, jobs, seed)
        },
        |&(machines, tasks, cores, vis_min, mean_s, stall, fail, jobs_n, seed)| {
            let cfg = AppConfig {
                cluster_machines: machines,
                tasks_per_machine: tasks,
                docker_cores: cores,
                machine_types: vec!["m5.xlarge".into()],
                machine_price: 0.10,
                sqs_message_visibility: vis_min * MINUTE,
                ..Default::default()
            };
            let jobs = JobSpec::plate("P", jobs_n as u32, 1, vec![]);
            let fleet = FleetSpec::template("us-east-1").unwrap();
            let mut ex = ModeledExecutor {
                model: DurationModel {
                    mean_s,
                    cv: 0.4,
                    stall_prob: stall,
                    fail_prob: fail,
                },
                ..Default::default()
            };
            let opts = RunOptions {
                seed,
                max_sim_time: 3 * 24 * HOUR,
                ..Default::default()
            };
            let report = run_full(&cfg, &jobs, &fleet, &mut ex, opts)
                .map_err(|e| e.to_string())?;
            if !report.fully_accounted() {
                return Err(format!("jobs unaccounted: {}", report.summary()));
            }
            if !report.cleaned_up {
                return Err(format!("no cleanup: {}", report.summary()));
            }
            if report.cost.total_usd() <= 0.0 {
                return Err("zero cost for a real run".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Sweep-aggregation invariants (DESIGN.md §5/§6)
// ---------------------------------------------------------------------------

/// Random synthetic RunReport: non-negative counters and cost, sometimes
/// drained, sometimes not.
fn gen_report(rng: &mut SimRng) -> RunReport {
    let submitted = 1 + rng.below(500);
    let completed = rng.below(submitted + 1);
    let dead_lettered = rng.below(submitted - completed + 1);
    let drained_at = rng.chance(0.8).then(|| 1 + rng.below(48 * HOUR));
    let machine_hours = rng.f64() * 100.0;
    RunReport {
        stats: RunStats {
            completed,
            skipped_done: rng.below(50),
            duplicates: rng.below(20),
            dead_lettered,
            instances_launched: rng.below(64),
            interruptions: rng.below(16),
            lost_to_death: rng.below(8),
            ..Default::default()
        },
        drained_at,
        ended_at: drained_at.unwrap_or(0) + rng.below(12 * HOUR),
        cleaned_up: rng.chance(0.9),
        cost: CostReport {
            ec2_usd: machine_hours * 0.03,
            sqs_usd: rng.f64() * 0.01,
            s3_usd: rng.f64() * 0.01,
            cloudwatch_usd: rng.f64() * 0.01,
            machine_hours,
            on_demand_equivalent_usd: machine_hours * 0.096,
        },
        jobs_submitted: submitted,
    }
}

#[test]
fn prop_aggregate_order_statistics() {
    // For any sample: n matches, min <= p50 <= p95 <= max, mean within
    // [min, max], and the summary is permutation-invariant bit-for-bit.
    forall_r(
        "aggregate-order-statistics",
        80,
        0xA66,
        |rng| {
            let n = rng.below(40) as usize;
            (0..n).map(|_| rng.lognormal_mean_cv(100.0, 1.0)).collect::<Vec<f64>>()
        },
        |xs| {
            let a = Aggregate::from_values(xs);
            if a.n != xs.len() {
                return Err(format!("n={} len={}", a.n, xs.len()));
            }
            if xs.is_empty() {
                return (a == Aggregate::from_values(&[]))
                    .then_some(())
                    .ok_or_else(|| "empty aggregate not canonical".into());
            }
            if !(a.min <= a.p50 && a.p50 <= a.p95 && a.p95 <= a.max) {
                return Err(format!("order violated: {a:?}"));
            }
            if !(a.min <= a.mean && a.mean <= a.max) {
                return Err(format!("mean outside range: {a:?}"));
            }
            let mut rev = xs.clone();
            rev.reverse();
            if Aggregate::from_values(&rev) != a {
                return Err("not permutation-invariant".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scenario_summary_conserves_totals() {
    // Aggregate job totals equal the sum of per-cell totals, rates stay
    // in [0, 1], cost is non-negative, and p50 <= p95 on every aggregate.
    forall_r(
        "scenario-summary-totals",
        60,
        0x5CE,
        |rng| {
            let n = 1 + rng.below(8) as usize;
            (0..n).map(|_| gen_report(rng)).collect::<Vec<RunReport>>()
        },
        |reports| {
            let refs: Vec<&RunReport> = reports.iter().collect();
            let s = ScenarioSummary::from_reports("p", &refs);
            let sum = |f: fn(&RunReport) -> u64| -> u64 { reports.iter().map(f).sum() };
            if s.jobs_submitted != sum(|r| r.jobs_submitted)
                || s.completed != sum(|r| r.stats.completed)
                || s.skipped_done != sum(|r| r.stats.skipped_done)
                || s.dead_lettered != sum(|r| r.stats.dead_lettered)
                || s.duplicates != sum(|r| r.stats.duplicates)
                || s.instances_launched != sum(|r| r.stats.instances_launched)
                || s.interruptions != sum(|r| r.stats.interruptions)
            {
                return Err(format!("summed counters drifted: {s:?}"));
            }
            if s.cells != reports.len() {
                return Err(format!("cells={} != {}", s.cells, reports.len()));
            }
            if s.drained != reports.iter().filter(|r| r.drained_at.is_some()).count() {
                return Err("drained count wrong".into());
            }
            if s.makespan_s.n != s.drained || s.jobs_per_hour.n != s.drained {
                return Err("drained-only aggregates cover wrong sample".into());
            }
            for (name, a) in [
                ("makespan", &s.makespan_s),
                ("jobs/h", &s.jobs_per_hour),
                ("cost", &s.cost_usd),
                ("dup-rate", &s.duplicate_rate),
                ("dlq-rate", &s.dead_letter_rate),
            ] {
                if a.p50 > a.p95 {
                    return Err(format!("{name}: p50 > p95: {a:?}"));
                }
                if a.min < 0.0 {
                    return Err(format!("{name}: negative: {a:?}"));
                }
            }
            if s.duplicate_rate.max > 1.0 || s.dead_letter_rate.max > 1.0 {
                return Err("rate above 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cost_nonnegative_and_monotone_in_billed_hours() {
    // Scaling every cell's billed machine-hours (at a fixed hourly rate)
    // by lambda >= 1 never decreases any cost aggregate; cost is never
    // negative.
    forall_r(
        "cost-monotone-in-hours",
        60,
        0xC057,
        |rng| {
            let n = 1 + rng.below(6) as usize;
            let reports: Vec<RunReport> = (0..n).map(|_| gen_report(rng)).collect();
            let lambda = 1.0 + rng.f64() * 4.0;
            (reports, lambda)
        },
        |(reports, lambda)| {
            let refs: Vec<&RunReport> = reports.iter().collect();
            let base = ScenarioSummary::from_reports("c", &refs);
            let scaled_reports: Vec<RunReport> = reports
                .iter()
                .map(|r| {
                    let mut r = r.clone();
                    r.cost.machine_hours *= lambda;
                    r.cost.ec2_usd *= lambda; // same $/hour, more hours
                    r
                })
                .collect();
            let scaled_refs: Vec<&RunReport> = scaled_reports.iter().collect();
            let scaled = ScenarioSummary::from_reports("c", &scaled_refs);
            if base.cost_usd.min < 0.0 {
                return Err(format!("negative cost: {:?}", base.cost_usd));
            }
            for (name, b, s) in [
                ("mean", base.cost_usd.mean, scaled.cost_usd.mean),
                ("p50", base.cost_usd.p50, scaled.cost_usd.p50),
                ("p95", base.cost_usd.p95, scaled.cost_usd.p95),
                ("max", base.cost_usd.max, scaled.cost_usd.max),
            ] {
                if s < b {
                    return Err(format!(
                        "cost {name} decreased with more billed hours: {b} -> {s} (lambda={lambda})"
                    ));
                }
            }
            Ok(())
        },
    );
}
