//! Golden-snapshot gates for the report JSON schemas.
//!
//! The *field sets* of `ds run --json` and `ds sweep --json` are pinned
//! against checked-in fixtures (`tests/golden/*.keys`), so schema drift
//! — a renamed key, a dropped object, an accidentally-omitted new field
//! — fails loudly here instead of silently breaking downstream parsers.
//! Values are deliberately not pinned (they are covered by the
//! determinism suite); only the shape is.
//!
//! To update after an intentional schema change: the failure message
//! prints the full actual key list — paste it over the fixture body.

use std::collections::BTreeSet;

use ds_rs::coordinator::autoscale::{ScalingMode, ScalingPolicy};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::coordinator::sweep::{run_sweep, SweepPlan};
use ds_rs::json::Value;
use ds_rs::testutil::fixtures::{modeled, plate_jobs, quick_cfg, template_fleet};
use ds_rs::workflow::{SharingMode, WorkflowSpec};
use ds_rs::workloads::dag;

/// Collect every key path in `v`: object fields as `a.b.c`, array
/// elements as `a[]` (first element only — rows share one shape).
fn key_paths(v: &Value, prefix: &str, out: &mut BTreeSet<String>) {
    match v {
        Value::Obj(fields) => {
            for (k, val) in fields {
                let path = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                out.insert(path.clone());
                key_paths(val, &path, out);
            }
        }
        Value::Arr(items) => {
            if let Some(first) = items.first() {
                key_paths(first, &format!("{prefix}[]"), out);
            }
        }
        _ => {}
    }
}

fn paths_of(v: &Value) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    key_paths(v, "", &mut out);
    out
}

fn assert_matches_golden(actual: &BTreeSet<String>, fixture: &str) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(fixture);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden fixture {}: {e}", path.display()));
    let want: BTreeSet<String> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    if *actual != want {
        let added: Vec<&String> = actual.difference(&want).collect();
        let removed: Vec<&String> = want.difference(actual).collect();
        panic!(
            "report JSON schema drifted from tests/golden/{fixture}\n\
             keys not in the fixture: {added:?}\n\
             fixture keys now missing: {removed:?}\n\
             If this change is intentional, replace the fixture body with:\n{}",
            actual.iter().cloned().collect::<Vec<_>>().join("\n")
        );
    }
}

/// A deterministic elastic run whose controller provably decides at
/// least once, so the `scaling.timeline[]` row shape is exercised.
fn elastic_report() -> ds_rs::metrics::RunReport {
    let cfg = quick_cfg(3);
    let jobs = plate_jobs(12, 2); // 24 jobs, mean 300 s: scale-in fires
    let opts = RunOptions {
        scaling: Some(ScalingPolicy::target_tracking(8.0)),
        ..Default::default()
    };
    let mut ex = modeled(300.0);
    run_full(&cfg, &jobs, &template_fleet(), &mut ex, opts).unwrap()
}

#[test]
fn run_report_json_field_set_is_pinned() {
    let report = elastic_report();
    assert!(
        report.scaling.decisions >= 1,
        "golden run must exercise the timeline: {:?}",
        report.scaling
    );
    assert_matches_golden(&paths_of(&report.to_json()), "run_report.keys");
}

#[test]
fn sweep_report_json_field_set_is_pinned() {
    // One scenario engaging the optional axes whose JSON keys are
    // conditional: INPUT_MB (non-zero), the two scaling axes, and the
    // two workflow axes (WORKFLOW only labels DAG scenarios; SHARING
    // only labels non-default modes).
    let plan = SweepPlan::builder()
        .config(quick_cfg(2))
        .jobs(plate_jobs(2, 1))
        .seeds([1])
        .machines([2])
        .input_mbs([8.0])
        .scalings([ScalingMode::TargetTracking])
        .scaling_targets([2.0])
        .job_mean_s([30.0])
        .workflows([Some(dag::diamond())])
        .sharings([SharingMode::NodeLocal])
        .build()
        .unwrap();
    let run = run_sweep(&plan, 2).unwrap();
    assert_matches_golden(&paths_of(&run.report.to_json()), "sweep_report.keys");
}

// ---------------------------------------------------------------------
// DAG workflow schemas (DESIGN.md §11): the WORKFLOW file format and
// the workflow slice of the run report, stage rows included.
// ---------------------------------------------------------------------

/// A deterministic DAG run — diamond over node-local sharing — so the
/// report's workflow slice has releases, staged bytes, and stage spans.
fn dag_report() -> ds_rs::metrics::RunReport {
    let cfg = quick_cfg(3);
    let opts = RunOptions {
        workflow: Some(dag::diamond()),
        sharing: SharingMode::NodeLocal,
        ..Default::default()
    };
    let mut ex = modeled(60.0);
    run_full(&cfg, &plate_jobs(2, 1), &template_fleet(), &mut ex, opts).unwrap()
}

#[test]
fn workflow_run_report_field_set_pins_stage_rows() {
    let report = dag_report();
    assert!(report.drained_at.is_some(), "golden DAG run must drain");
    assert!(report.workflow.releases > 0, "must exercise releases");
    assert!(
        !report.workflow.stages.is_empty(),
        "must exercise the stage rows — key_paths only walks populated arrays"
    );
    assert_matches_golden(&paths_of(&report.to_json()), "workflow_run_report.keys");
}

#[test]
fn workflow_file_field_set_is_pinned_and_render_is_bit_stable() {
    for name in dag::SHAPES {
        let spec = dag::shape(name).unwrap();
        assert_matches_golden(&paths_of(&spec.to_json()), "workflow_spec.keys");
        // render → parse → render is byte-stable: WORKFLOW files and the
        // inline axis objects in rendered Sweep files share this codec,
        // so any asymmetry would desynchronise shard workers.
        let text = spec.render();
        let back = WorkflowSpec::parse(&text).unwrap();
        assert_eq!(back, spec, "{name}: parse must invert render");
        assert_eq!(back.render(), text, "{name}: render must be bit-stable");
    }
}

// ---------------------------------------------------------------------
// Topology schemas (DESIGN.md §12): the TOPOLOGY file format and the
// topology slice of the run report, domain and outage rows included.
// ---------------------------------------------------------------------

use ds_rs::topology::{ClusterTopology, FaultKind, Placement};

/// A deterministic multi-domain run — two regions, spread placement, an
/// AZ outage on the remote domain — so the report carries the
/// conditional `topology` object with domain rows and an outage window.
fn topology_report() -> ds_rs::metrics::RunReport {
    let cfg = quick_cfg(3);
    let topo = ClusterTopology::builder("two-region")
        .domain("us-east-1a", "us-east-1")
        .domain("us-west-2a", "us-west-2")
        .fault(FaultKind::AzOutage, "us-west-2a", 5, 30, 1.0)
        .build()
        .unwrap();
    let opts = RunOptions {
        scaling: Some(ScalingPolicy::target_tracking(8.0)),
        topology: Some(topo),
        placement: Placement::Spread,
        ..Default::default()
    };
    let mut ex = modeled(300.0);
    run_full(&cfg, &plate_jobs(12, 2), &template_fleet(), &mut ex, opts).unwrap()
}

#[test]
fn topology_run_report_field_set_pins_domain_rows() {
    let report = topology_report();
    assert!(
        report.scaling.decisions >= 1,
        "golden topology run must exercise the scaling timeline: {:?}",
        report.scaling
    );
    assert!(
        !report.topology.domains.is_empty(),
        "must exercise the domain rows — key_paths only walks populated arrays"
    );
    assert!(
        !report.topology.outages.is_empty(),
        "must exercise the outage rows"
    );
    assert_matches_golden(&paths_of(&report.to_json()), "topology_run_report.keys");
}

#[test]
fn topology_file_field_set_is_pinned_and_render_is_bit_stable() {
    // The golden spec carries a fault so the FAULTS row shape is pinned
    // too (the built-in shapes all have empty fault lists).
    let faulted = ClusterTopology::builder("golden")
        .domain("us-east-1a", "us-east-1")
        .domain("us-west-2a", "us-west-2")
        .fault(FaultKind::AzOutage, "us-east-1a", 5, 10, 1.0)
        .build()
        .unwrap();
    assert_matches_golden(&paths_of(&faulted.to_json()), "topology_spec.keys");
    let text = faulted.render();
    assert_eq!(ClusterTopology::parse(&text).unwrap(), faulted);
    assert_eq!(ClusterTopology::parse(&text).unwrap().render(), text);
    for name in ClusterTopology::SHAPES {
        // render → parse → render is byte-stable: TOPOLOGY files and the
        // inline axis objects in rendered Sweep files share this codec,
        // so any asymmetry would desynchronise shard workers.
        let spec = ClusterTopology::shape(name).unwrap();
        let text = spec.render();
        let back = ClusterTopology::parse(&text).unwrap();
        assert_eq!(back, spec, "{name}: parse must invert render");
        assert_eq!(back.render(), text, "{name}: render must be bit-stable");
    }
}

// ---------------------------------------------------------------------
// Traffic schemas (DESIGN.md §13): the TRAFFIC file format and the
// traffic slice of the run report, per-tenant rows included.
// ---------------------------------------------------------------------

use ds_rs::traffic::{QueueingPolicy, TrafficSpec};

/// A deterministic multi-tenant run — the noisy-neighbor shape under
/// fair-share — so the report carries the conditional `traffic` object
/// with populated tenant rows.
fn traffic_report() -> ds_rs::metrics::RunReport {
    let cfg = quick_cfg(3);
    let opts = RunOptions {
        traffic: TrafficSpec::shape("noisy-neighbor"),
        queueing: QueueingPolicy::FairShare,
        ..Default::default()
    };
    let mut ex = modeled(60.0);
    run_full(&cfg, &plate_jobs(2, 1), &template_fleet(), &mut ex, opts).unwrap()
}

#[test]
fn traffic_run_report_field_set_pins_tenant_rows() {
    let report = traffic_report();
    assert!(report.drained_at.is_some(), "golden traffic run must drain");
    assert_eq!(
        report.traffic.tenants.len(),
        2,
        "must exercise the tenant rows — key_paths only walks populated arrays"
    );
    assert!(
        report.traffic.tenants.iter().all(|t| t.completed > 0),
        "every tenant must complete work: {:?}",
        report.traffic
    );
    assert_matches_golden(&paths_of(&report.to_json()), "traffic_run_report.keys");
}

#[test]
fn traffic_file_field_set_is_pinned_and_render_is_bit_stable() {
    for name in TrafficSpec::SHAPES {
        let spec = TrafficSpec::shape(name).unwrap();
        assert_matches_golden(&paths_of(&spec.to_json()), "traffic_spec.keys");
        // render → parse → render is byte-stable: TRAFFIC files and the
        // inline axis objects in rendered Sweep files share this codec,
        // so any asymmetry would desynchronise shard workers.
        let text = spec.render();
        let back = TrafficSpec::parse(&text).unwrap();
        assert_eq!(back, spec, "{name}: parse must invert render");
        assert_eq!(back.render(), text, "{name}: render must be bit-stable");
    }
}

#[test]
fn run_and_sweep_json_round_trip_through_the_parser() {
    // The emitted JSON is valid and value-stable through parse→pretty.
    let j = elastic_report().to_json();
    let parsed = ds_rs::json::parse(&j.pretty()).unwrap();
    assert_eq!(parsed, j);
}

// ---------------------------------------------------------------------
// Shard wire envelopes (DESIGN.md §10): the field sets both halves of
// the parent/child contract speak.  A drift here is a wire break, not
// just a schema change — it must come with a WIRE_VERSION bump.
// ---------------------------------------------------------------------

use ds_rs::coordinator::shard::{shard_plan, shard_worker, SweepShardRequest, WIRE_VERSION};

/// One elastic data-shaped cell, so the result envelope exercises every
/// report family: pools, data plane, and a non-empty scaling timeline.
fn shard_golden_plan() -> SweepPlan {
    SweepPlan::builder()
        .config(quick_cfg(3))
        .jobs(plate_jobs(12, 2))
        .seeds([1])
        .machines([3])
        .input_mbs([8.0])
        .scalings([ScalingMode::TargetTracking])
        .scaling_targets([8.0])
        .job_mean_s([300.0])
        .build()
        .unwrap()
}

#[test]
fn shard_request_envelope_field_set_is_pinned() {
    let plan = shard_golden_plan();
    let req = SweepShardRequest {
        plan,
        threads: 2,
        assignment: shard_plan(1, 1)[0].clone(),
    };
    // The embedded "plan" subtree is the Sweep file schema, pinned by
    // its own round-trip gate (tests/scenario_api.rs) — pinning it
    // again here would make every new axis a wire-fixture churn.  Only
    // the envelope proper is golden.
    let paths: BTreeSet<String> = paths_of(&req.to_json())
        .into_iter()
        .filter(|p| p == "plan" || !p.starts_with("plan."))
        .collect();
    assert_matches_golden(&paths, "shard_request.keys");
}

#[test]
fn shard_result_envelope_field_set_is_pinned() {
    let plan = shard_golden_plan();
    let req = SweepShardRequest {
        plan,
        threads: 2,
        assignment: shard_plan(1, 1)[0].clone(),
    };
    let out = shard_worker(&req.to_json().pretty()).unwrap();
    let v = ds_rs::json::parse(&out).unwrap();
    // key_paths only walks the first array element, so the one golden
    // cell must populate every optional family.
    let report = v.get("cells").unwrap().as_arr().unwrap()[0].get("report").unwrap();
    let scaling = report.get("scaling").unwrap();
    assert!(
        scaling.get("decisions").unwrap().as_u64().unwrap() >= 1,
        "golden cell must exercise the scaling timeline"
    );
    assert!(
        report.get("data").unwrap().get("bytes_downloaded").unwrap().as_u64().unwrap() > 0,
        "golden cell must exercise the data plane"
    );
    assert!(
        !report.get("pools").unwrap().as_arr().unwrap().is_empty(),
        "golden cell must have pool rows"
    );
    assert_matches_golden(&paths_of(&v), "shard_result.keys");
}

#[test]
fn version_bumped_result_envelope_is_rejected() {
    use ds_rs::coordinator::shard::{ShardResult, WireError};
    let plan = shard_golden_plan();
    let req = SweepShardRequest {
        plan,
        threads: 1,
        assignment: shard_plan(1, 1)[0].clone(),
    };
    let out = shard_worker(&req.to_json().pretty()).unwrap();
    let bumped = match ds_rs::json::parse(&out).unwrap() {
        Value::Obj(fields) => Value::Obj(
            fields
                .into_iter()
                .map(|(k, val)| {
                    if k == "version" {
                        (k, Value::from(WIRE_VERSION + 1))
                    } else {
                        (k, val)
                    }
                })
                .collect(),
        ),
        other => other,
    };
    match ShardResult::from_json(&bumped) {
        Err(WireError::Version { got, want }) => {
            assert_eq!(got, WIRE_VERSION + 1);
            assert_eq!(want, WIRE_VERSION);
        }
        other => panic!("expected a version error, got {other:?}"),
    }
}
