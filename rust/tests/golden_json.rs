//! Golden-snapshot gates for the report JSON schemas.
//!
//! The *field sets* of `ds run --json` and `ds sweep --json` are pinned
//! against checked-in fixtures (`tests/golden/*.keys`), so schema drift
//! — a renamed key, a dropped object, an accidentally-omitted new field
//! — fails loudly here instead of silently breaking downstream parsers.
//! Values are deliberately not pinned (they are covered by the
//! determinism suite); only the shape is.
//!
//! To update after an intentional schema change: the failure message
//! prints the full actual key list — paste it over the fixture body.

use std::collections::BTreeSet;

use ds_rs::coordinator::autoscale::{ScalingMode, ScalingPolicy};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::coordinator::sweep::{run_sweep, SweepPlan};
use ds_rs::json::Value;
use ds_rs::testutil::fixtures::{modeled, plate_jobs, quick_cfg, template_fleet};

/// Collect every key path in `v`: object fields as `a.b.c`, array
/// elements as `a[]` (first element only — rows share one shape).
fn key_paths(v: &Value, prefix: &str, out: &mut BTreeSet<String>) {
    match v {
        Value::Obj(fields) => {
            for (k, val) in fields {
                let path = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                out.insert(path.clone());
                key_paths(val, &path, out);
            }
        }
        Value::Arr(items) => {
            if let Some(first) = items.first() {
                key_paths(first, &format!("{prefix}[]"), out);
            }
        }
        _ => {}
    }
}

fn paths_of(v: &Value) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    key_paths(v, "", &mut out);
    out
}

fn assert_matches_golden(actual: &BTreeSet<String>, fixture: &str) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(fixture);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden fixture {}: {e}", path.display()));
    let want: BTreeSet<String> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    if *actual != want {
        let added: Vec<&String> = actual.difference(&want).collect();
        let removed: Vec<&String> = want.difference(actual).collect();
        panic!(
            "report JSON schema drifted from tests/golden/{fixture}\n\
             keys not in the fixture: {added:?}\n\
             fixture keys now missing: {removed:?}\n\
             If this change is intentional, replace the fixture body with:\n{}",
            actual.iter().cloned().collect::<Vec<_>>().join("\n")
        );
    }
}

/// A deterministic elastic run whose controller provably decides at
/// least once, so the `scaling.timeline[]` row shape is exercised.
fn elastic_report() -> ds_rs::metrics::RunReport {
    let cfg = quick_cfg(3);
    let jobs = plate_jobs(12, 2); // 24 jobs, mean 300 s: scale-in fires
    let opts = RunOptions {
        scaling: Some(ScalingPolicy::target_tracking(8.0)),
        ..Default::default()
    };
    let mut ex = modeled(300.0);
    run_full(&cfg, &jobs, &template_fleet(), &mut ex, opts).unwrap()
}

#[test]
fn run_report_json_field_set_is_pinned() {
    let report = elastic_report();
    assert!(
        report.scaling.decisions >= 1,
        "golden run must exercise the timeline: {:?}",
        report.scaling
    );
    assert_matches_golden(&paths_of(&report.to_json()), "run_report.keys");
}

#[test]
fn sweep_report_json_field_set_is_pinned() {
    // One scenario engaging the optional axes whose JSON keys are
    // conditional: INPUT_MB (non-zero) and the two scaling axes.
    let plan = SweepPlan::builder()
        .config(quick_cfg(2))
        .jobs(plate_jobs(2, 1))
        .seeds([1])
        .machines([2])
        .input_mbs([8.0])
        .scalings([ScalingMode::TargetTracking])
        .scaling_targets([2.0])
        .job_mean_s([30.0])
        .build()
        .unwrap();
    let run = run_sweep(&plan, 2).unwrap();
    assert_matches_golden(&paths_of(&run.report.to_json()), "sweep_report.keys");
}

#[test]
fn run_and_sweep_json_round_trip_through_the_parser() {
    // The emitted JSON is valid and value-stable through parse→pretty.
    let j = elastic_report().to_json();
    let parsed = ds_rs::json::parse(&j.pretty()).unwrap();
    assert_eq!(parsed, j);
}
