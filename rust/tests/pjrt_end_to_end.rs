//! Integration: the full four-command flow with the REAL PJRT executor —
//! the paper's architecture end-to-end: Python never runs; the Rust
//! workers execute the AOT-compiled XLA pipelines and write real outputs
//! into simulated S3.  Requires `make artifacts`.

use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{RunOptions, Simulation};
use ds_rs::json::parse;
use ds_rs::runtime::PjrtRuntime;
use ds_rs::sim::MINUTE;
use ds_rs::workloads::PjrtExecutor;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(dir)
        .join("manifest.json")
        .exists()
        .then(|| dir.to_string())
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn cfg(workload: &str, expected_files: u32) -> AppConfig {
    let mut c = AppConfig {
        workload_id: workload.into(),
        cluster_machines: 2,
        tasks_per_machine: 2,
        docker_cores: 1,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: 5 * MINUTE,
        ..Default::default()
    };
    c.check_if_done.expected_number_files = expected_files;
    c
}

#[test]
fn cellprofiler_plate_real_compute() {
    let dir = require_artifacts!();
    let cfg = cfg("cp_128_b1", 1);
    let jobs = JobSpec::plate("PJRT-P1", 4, 2, vec![]); // 8 jobs
    let mut sim = Simulation::new(cfg, RunOptions::default()).unwrap();
    sim.submit(&jobs).unwrap();
    sim.start(&FleetSpec::template("us-east-1").unwrap()).unwrap();
    let runtime = PjrtRuntime::new(&dir).unwrap();
    let mut ex = PjrtExecutor::new(runtime, "cp_128_b1").unwrap();
    // Scale measured ms so jobs take simulated minutes like real CP jobs.
    ex.time_scale = 1_000.0;
    let report = sim.run(&mut ex).unwrap();
    assert_eq!(report.stats.completed, 8, "{}", report.summary());
    assert!(report.cleaned_up);

    // Real CSVs landed in S3 with real feature values.
    let outputs = sim.acct.s3.list_prefix("ds-data", "output/PJRT-P1/");
    assert_eq!(outputs.len(), 8, "{outputs:?}");
    let (key, _) = &outputs[0];
    let obj = sim.acct.s3.get("ds-data", key).unwrap();
    let csv = String::from_utf8(obj.body.bytes().unwrap().to_vec()).unwrap();
    assert!(csv.starts_with("site,fg_mean,fg_std,"), "{csv}");
    let data_line = csv.lines().nth(1).unwrap();
    let fields: Vec<&str> = data_line.split(',').collect();
    assert_eq!(fields.len(), 17); // site + 16 features
    let fg_mean: f32 = fields[1].parse().unwrap();
    let bg_mean: f32 = fields[6].parse().unwrap();
    assert!(fg_mean > bg_mean, "foreground brighter: {fg_mean} vs {bg_mean}");
}

#[test]
fn omezarr_conversion_writes_chunked_store() {
    let dir = require_artifacts!();
    // 4-level pyramid over 256²: 27 objects per job (22 chunks + 5 meta).
    let cfg = cfg("pyramid_256_l4", 27);
    let jobs = JobSpec {
        shared: vec![("output_prefix".into(), "zarr-out".into())],
        groups: (0..3)
            .map(|i| {
                vec![(
                    "Metadata_Image".to_string(),
                    ds_rs::json::Value::Str(format!("img{i}")),
                )]
            })
            .collect(),
    };
    let mut sim = Simulation::new(cfg, RunOptions::default()).unwrap();
    sim.submit(&jobs).unwrap();
    sim.start(&FleetSpec::template("us-east-1").unwrap()).unwrap();
    let runtime = PjrtRuntime::new(&dir).unwrap();
    let mut ex = PjrtExecutor::new(runtime, "pyramid_256_l4").unwrap();
    ex.time_scale = 1_000.0;
    let report = sim.run(&mut ex).unwrap();
    assert_eq!(report.stats.completed, 3, "{}", report.summary());

    // Store layout: .zattrs + per-level .zarray + chunks.
    let store = "zarr-out/img0/image.zarr";
    let listed = sim.acct.s3.list_prefix("ds-data", store);
    assert_eq!(listed.len(), 27, "{listed:?}");
    let attrs = sim
        .acct
        .s3
        .get("ds-data", &format!("{store}/.zattrs"))
        .unwrap();
    let attrs_json =
        parse(std::str::from_utf8(attrs.body.bytes().unwrap()).unwrap()).unwrap();
    let ms = &attrs_json.get("multiscales").unwrap().as_arr().unwrap()[0];
    assert_eq!(ms.get("datasets").unwrap().as_arr().unwrap().len(), 4);
    // A chunk has exactly 64*64 f32s.
    let chunk = sim
        .acct
        .s3
        .get("ds-data", &format!("{store}/0/0.0"))
        .unwrap();
    assert_eq!(chunk.body.len(), 64 * 64 * 4);
}

#[test]
fn stitch_run_produces_montage() {
    let dir = require_artifacts!();
    let cfg = cfg("stitch_g2_t128_o16", 2);
    let jobs = JobSpec {
        shared: vec![("output_prefix".into(), "stitched".into())],
        groups: vec![vec![(
            "Metadata_Montage".to_string(),
            ds_rs::json::Value::Str("M0".into()),
        )]],
    };
    let mut sim = Simulation::new(cfg, RunOptions::default()).unwrap();
    sim.submit(&jobs).unwrap();
    sim.start(&FleetSpec::template("us-east-1").unwrap()).unwrap();
    let runtime = PjrtRuntime::new(&dir).unwrap();
    let mut ex = PjrtExecutor::new(runtime, "stitch_g2_t128_o16").unwrap();
    ex.time_scale = 1_000.0;
    let report = sim.run(&mut ex).unwrap();
    assert_eq!(report.stats.completed, 1, "{}", report.summary());
    let side = 2 * 128 - 16;
    let montage = sim
        .acct
        .s3
        .get(
            "ds-data",
            &format!("stitched/M0/montage_{side}x{side}.f32"),
        )
        .unwrap();
    assert_eq!(montage.body.len() as usize, side * side * 4);
    let scores = sim
        .acct
        .s3
        .get("ds-data", "stitched/M0/seam_scores.csv")
        .unwrap();
    let csv = String::from_utf8(scores.body.bytes().unwrap().to_vec()).unwrap();
    assert!(csv.starts_with("seam,ncc\n"));
    // All four seams scored, strongly correlated (tiles share a field).
    for line in csv.lines().skip(1) {
        let ncc: f32 = line.split(',').nth(1).unwrap().parse().unwrap();
        assert!(ncc > 0.7, "{csv}");
    }
}

#[test]
fn check_if_done_skips_on_rerun_with_real_outputs() {
    let dir = require_artifacts!();
    let cfg_run = cfg("cp_128_b1", 1);
    let jobs = JobSpec::plate("RERUN", 2, 2, vec![]); // 4 jobs

    // First run.
    let mut sim = Simulation::new(cfg_run.clone(), RunOptions::default()).unwrap();
    sim.submit(&jobs).unwrap();
    sim.start(&FleetSpec::template("us-east-1").unwrap()).unwrap();
    let runtime = PjrtRuntime::new(&dir).unwrap();
    let mut ex = PjrtExecutor::new(runtime, "cp_128_b1").unwrap();
    ex.time_scale = 1_000.0;
    let r1 = sim.run(&mut ex).unwrap();
    assert_eq!(r1.stats.completed, 4);

    // Second run over the same outputs: everything skips.
    let outputs: Vec<(String, Vec<u8>)> = sim
        .acct
        .s3
        .list_prefix("ds-data", "output/")
        .into_iter()
        .map(|(k, _)| {
            let body = sim.acct.s3.get("ds-data", &k).unwrap().body.bytes().unwrap().to_vec();
            (k, body)
        })
        .collect();
    let mut sim2 = Simulation::new(cfg_run, RunOptions::default()).unwrap();
    sim2.stage(|acct| {
        for (k, body) in &outputs {
            acct.s3
                .put("ds-data", k, ds_rs::aws::s3::Body::Bytes(body.clone()), 0)
                .unwrap();
        }
    });
    sim2.submit(&jobs).unwrap();
    sim2.start(&FleetSpec::template("us-east-1").unwrap()).unwrap();
    let runtime2 = PjrtRuntime::new(&dir).unwrap();
    let mut ex2 = PjrtExecutor::new(runtime2, "cp_128_b1").unwrap();
    ex2.time_scale = 1_000.0;
    let r2 = sim2.run(&mut ex2).unwrap();
    assert_eq!(r2.stats.skipped_done, 4, "{}", r2.summary());
    assert_eq!(r2.stats.completed, 0);
}
