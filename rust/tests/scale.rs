//! Macro-scale gates for the event core (ISSUE 6).
//!
//! The headline test is paper-scale — one million jobs across one
//! thousand machines — and is `#[ignore]` by default so `cargo test`
//! stays fast; the release CI lane runs it with `-- --ignored` where the
//! optimized build finishes inside the wall-clock budget.  A mid-scale
//! smoke stays in the default run so the conservation invariant is
//! exercised on every push.

use std::time::Instant;

use ds_rs::config::{FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, EngineOptions, RunOptions};
use ds_rs::sim::{QueueKind, StoreKind};
use ds_rs::testutil::fixtures::{modeled, quick_cfg};

/// One million jobs / one thousand machines, default engine (calendar
/// queue + dense stores).  Totals conserve exactly, the monitor cleans
/// up, and the whole simulation fits a wall-clock budget — the committed
/// perf trajectory's smoke-level floor (see `benchmark_compare.sh` for
/// the measured number).
#[test]
#[ignore = "macro-scale (1M jobs); the release CI lane runs it with --ignored"]
fn million_jobs_thousand_machines_complete_within_budget() {
    const WALL_BUDGET_S: u64 = 600;
    let mut cfg = quick_cfg(1000);
    // CHECK_IF_DONE lists S3 per job — an O(jobs) scan each time at this
    // scale, and irrelevant to a fresh run.
    cfg.check_if_done.enabled = false;
    let jobs = JobSpec::plate("P", 1000, 1000, vec![]);
    let mut fleet = FleetSpec::template("us-east-1").unwrap();
    // Spot pools cap out well below 1000 machines; take the fleet
    // on-demand so capacity actually reaches the target.
    fleet.on_demand_base = 1000;
    let mut ex = modeled(60.0);
    let started = Instant::now();
    let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default()).unwrap();
    let elapsed = started.elapsed();
    assert_eq!(report.jobs_submitted, 1_000_000);
    assert_eq!(report.stats.completed, 1_000_000, "{}", report.summary());
    assert!(report.fully_accounted(), "{}", report.summary());
    assert!(report.cleaned_up);
    assert!(
        elapsed.as_secs() < WALL_BUDGET_S,
        "million-job run took {elapsed:?} (budget {WALL_BUDGET_S}s)"
    );
}

/// Mid-scale smoke inside the default test run: 10k jobs on 100
/// machines, exact conservation, full cleanup — under all four
/// `{queue} × {store}` engine combinations, so the non-default engines
/// (the old binary heap, the hash-map stores) keep default-lane
/// coverage at a scale where their data structures actually churn.
#[test]
fn ten_thousand_jobs_conserve_totals_on_every_engine() {
    let engines = [
        EngineOptions {
            queue: QueueKind::Heap,
            store: StoreKind::Map,
        },
        EngineOptions {
            queue: QueueKind::Heap,
            store: StoreKind::Dense,
        },
        EngineOptions {
            queue: QueueKind::Calendar,
            store: StoreKind::Map,
        },
        EngineOptions {
            queue: QueueKind::Calendar,
            store: StoreKind::Dense,
        },
    ];
    for engine in engines {
        let mut cfg = quick_cfg(100);
        cfg.check_if_done.enabled = false;
        let jobs = JobSpec::plate("P", 100, 100, vec![]);
        let mut fleet = FleetSpec::template("us-east-1").unwrap();
        fleet.on_demand_base = 100;
        let mut ex = modeled(60.0);
        let opts = RunOptions {
            engine,
            ..Default::default()
        };
        let report = run_full(&cfg, &jobs, &fleet, &mut ex, opts).unwrap();
        assert_eq!(
            report.stats.completed,
            10_000,
            "{engine:?}: {}",
            report.summary()
        );
        assert!(report.fully_accounted(), "{engine:?}: {}", report.summary());
        assert!(report.cleaned_up, "{engine:?}");
    }
}
