//! Integration: failure-mode experiments — crashes, stalls, interruption
//! storms, poison jobs, visibility-timeout pathologies (T4/T5/T7/T8).

use ds_rs::aws::ec2::Volatility;
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::json::Value;
use ds_rs::sim::clock::SimTime;
use ds_rs::sim::{HOUR, MINUTE, SECOND};
use ds_rs::workloads::{DurationModel, ModeledExecutor};

fn cfg(machines: u32, visibility: SimTime) -> AppConfig {
    AppConfig {
        cluster_machines: machines,
        tasks_per_machine: 2,
        docker_cores: 2,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: visibility,
        ..Default::default()
    }
}

fn fleet_file() -> FleetSpec {
    FleetSpec::template("us-east-1").unwrap()
}

fn executor(model: DurationModel) -> ModeledExecutor {
    ModeledExecutor {
        model,
        ..Default::default()
    }
}

#[test]
fn interruption_storm_work_survives() {
    // T5: high volatility + bid barely above base -> repeated
    // interruptions; SQS redelivery still finishes every job.
    // Long enough (multi-hour) that high-volatility spikes hit the run.
    let mut c = cfg(4, 10 * MINUTE);
    c.machine_price = 0.192 * 0.31 * 1.10; // 10% above spot base
    let jobs = JobSpec::plate("P", 96, 4, vec![]); // 384 jobs
    let opts = RunOptions {
        volatility: Volatility::High,
        seed: 3,
        max_sim_time: 3 * 24 * HOUR,
        ..Default::default()
    };
    let mut ex = executor(DurationModel {
        mean_s: 240.0,
        cv: 0.3,
        ..Default::default()
    });
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, opts).unwrap();
    assert!(
        report.stats.interruptions > 0,
        "storm should interrupt: {}",
        report.summary()
    );
    assert!(report.fully_accounted(), "{}", report.summary());
    assert_eq!(report.stats.dead_lettered, 0);
    assert_eq!(
        report.stats.completed + report.stats.skipped_done,
        384,
        "{}",
        report.summary()
    );
}

#[test]
fn stalled_workers_recovered_by_alarm_reaper() {
    // T8: 10% of jobs wedge the worker.  The CPU<1%/15min alarm reaps
    // fully-wedged machines; redelivery finishes the work.
    let c = cfg(4, 8 * MINUTE);
    let jobs = JobSpec::plate("P", 16, 2, vec![]); // 32 jobs
    let opts = RunOptions {
        seed: 5,
        max_sim_time: 2 * 24 * HOUR,
        ..Default::default()
    };
    let mut ex = executor(DurationModel {
        mean_s: 60.0,
        cv: 0.2,
        stall_prob: 0.10,
        ..Default::default()
    });
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, opts).unwrap();
    assert!(report.stats.stalled > 0, "{}", report.summary());
    assert!(report.fully_accounted(), "{}", report.summary());
    assert!(report.cleaned_up);
}

#[test]
fn crashes_with_reaper_keep_throughput() {
    // Run must outlast crash-mttf + the 15-min alarm window several times
    // over so reaping demonstrably happens mid-run.
    let c = cfg(6, 10 * MINUTE);
    let jobs = JobSpec::plate("P", 96, 4, vec![]); // 384 jobs
    let opts = RunOptions {
        seed: 9,
        crash_mttf: Some(30 * MINUTE),
        max_sim_time: 2 * 24 * HOUR,
        ..Default::default()
    };
    let mut ex = executor(DurationModel {
        mean_s: 150.0,
        cv: 0.3,
        ..Default::default()
    });
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, opts).unwrap();
    assert!(report.stats.crashes > 0, "{}", report.summary());
    assert!(report.stats.alarm_terminations > 0, "{}", report.summary());
    assert!(report.fully_accounted(), "{}", report.summary());
}

#[test]
fn visibility_tradeoff_short_duplicates_long_waits() {
    // T4: sweep visibility around the mean job time.  Short -> duplicate
    // work; long -> slow recovery from stalls (longer makespan).
    let jobs = JobSpec::plate("P", 24, 2, vec![]); // 48 jobs
    let run_vis = |vis: SimTime, stall: f64, seed: u64| {
        let c = cfg(4, vis);
        let mut ex = executor(DurationModel {
            mean_s: 120.0,
            cv: 0.2,
            stall_prob: stall,
            ..Default::default()
        });
        run_full(
            &c,
            &jobs,
            &fleet_file(),
            &mut ex,
            RunOptions {
                seed,
                max_sim_time: 2 * 24 * HOUR,
                ..Default::default()
            },
        )
        .unwrap()
    };
    // Too short (30 s << 120 s mean): rampant duplicates.
    let short = run_vis(30 * SECOND, 0.0, 1);
    assert!(
        short.stats.duplicates > 5,
        "short visibility must duplicate: {}",
        short.summary()
    );
    // Sane (2x mean): almost none.
    let sane = run_vis(4 * MINUTE, 0.0, 1);
    assert!(
        sane.stats.duplicates <= 1,
        "sane visibility: {}",
        sane.summary()
    );
    // With stalls, a very long visibility means waiting much longer for
    // redelivery than a sane one.
    let sane_stall = run_vis(4 * MINUTE, 0.08, 2);
    let long_stall = run_vis(60 * MINUTE, 0.08, 2);
    assert!(sane_stall.fully_accounted());
    assert!(long_stall.fully_accounted());
    assert!(
        long_stall.makespan().unwrap() > sane_stall.makespan().unwrap(),
        "long vis {:?} should wait longer than sane {:?}",
        long_stall.makespan(),
        sane_stall.makespan()
    );
}

#[test]
fn dlq_bounds_poison_job_damage() {
    // T7: with a DLQ, a poison job is parked after max_receive_count
    // attempts and the cluster winds down; every good job completes.
    let c = cfg(3, 3 * MINUTE);
    let mut jobs = JobSpec::plate("P", 10, 2, vec![]); // 20 jobs
    jobs.groups[0].push(("poison".into(), Value::Bool(true)));
    jobs.groups[7].push(("poison".into(), Value::Bool(true)));
    let opts = RunOptions {
        seed: 13,
        max_sim_time: 24 * HOUR,
        ..Default::default()
    };
    let mut ex = executor(DurationModel {
        mean_s: 45.0,
        cv: 0.2,
        ..Default::default()
    });
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, opts).unwrap();
    assert_eq!(report.stats.completed, 18, "{}", report.summary());
    assert_eq!(report.stats.dead_lettered, 2);
    assert!(report.cleaned_up, "cluster must not spin forever");
    // Each poison job was attempted exactly max_receive_count times.
    assert!(report.stats.failed_attempts >= 2 * 5);
    // And the whole thing ended in bounded time.
    assert!(report.ended_at < 12 * HOUR, "{}", report.summary());
}

#[test]
fn without_dlq_poison_job_keeps_cluster_alive() {
    // Anti-test for T7: crank max_receive_count so high the poison job
    // effectively never dead-letters; the run only ends at max_sim_time
    // and the fleet keeps burning money the whole time.
    let mut c = cfg(2, 2 * MINUTE);
    c.max_receive_count = 100_000;
    let mut jobs = JobSpec::plate("P", 6, 1, vec![]);
    jobs.groups[0].push(("poison".into(), Value::Bool(true)));
    let opts = RunOptions {
        seed: 17,
        max_sim_time: 12 * HOUR,
        ..Default::default()
    };
    let mut ex = executor(DurationModel {
        mean_s: 30.0,
        cv: 0.1,
        ..Default::default()
    });
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, opts).unwrap();
    assert_eq!(report.stats.completed, 5);
    assert!(!report.cleaned_up, "{}", report.summary());
    assert_eq!(report.stats.dead_lettered, 0);
    // The cluster churned for ~12 simulated hours on one bad job.
    assert!(report.cost.ec2_usd > 0.05, "{}", report.summary());
}

#[test]
fn low_bid_run_waits_for_capacity_but_finishes() {
    // T10 shape: bid barely above base in a quiet market still fulfills,
    // just slower (fulfillment latency model).
    let mut c = cfg(4, 10 * MINUTE);
    c.machine_price = 0.192 * 0.31 * 1.02;
    let jobs = JobSpec::plate("P", 8, 2, vec![]);
    let opts = RunOptions {
        seed: 19,
        max_sim_time: 24 * HOUR,
        ..Default::default()
    };
    let mut ex = executor(DurationModel {
        mean_s: 60.0,
        cv: 0.2,
        ..Default::default()
    });
    let report = run_full(&c, &jobs, &fleet_file(), &mut ex, opts).unwrap();
    assert!(report.fully_accounted(), "{}", report.summary());
}
