//! Sweep-engine thread-scaling bench (acceptance gate: a 64-cell sweep
//! at 8 threads must beat 1 thread by >= 3x wall-clock), plus a
//! plan-expansion bench for the Scenario API v2 layer (Sweep-file parse
//! + cartesian expansion of a 1000-scenario matrix).
//!
//!     cargo bench --bench sweep
//!     cargo bench --bench sweep -- --shards [--json]
//!
//! Each cell is an independent discrete-event simulation, so the engine
//! is embarrassingly parallel; the only serial parts are plan expansion
//! and the final aggregation.  The bench also cross-checks that every
//! thread count produced the bit-identical SweepReport — perf must never
//! buy nondeterminism.
//!
//! `--shards` benches the sharded dispatch path instead: the same
//! 64-cell plan across 1/2/4/8 real `ds shard-worker` processes
//! (2 threads each), bit-identity-checked against single-process
//! `run_sweep`; `benchmark_compare.sh --shards` drives the `--json`
//! output and diffs it against the committed `BENCH_7.json` snapshot.

use std::time::{Duration, Instant};

use ds_rs::aws::ec2::Volatility;
use ds_rs::config::{AppConfig, JobSpec};
use ds_rs::coordinator::shard::{run_sweep_sharded, ProcessExecutor, ShardOptions};
use ds_rs::coordinator::sweep::{run_sweep, ScenarioMatrix, SweepPlan};
use ds_rs::json::Value;
use ds_rs::scenario::SweepFile;
use ds_rs::sim::MINUTE;
use ds_rs::workloads::DurationModel;

fn plan_64_cells() -> SweepPlan {
    let cfg = AppConfig {
        cluster_machines: 4,
        tasks_per_machine: 2,
        docker_cores: 2,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: 10 * MINUTE,
        ..Default::default()
    };
    // 8 seeds x (2 machines x 2 visibilities x 2 models) = 64 cells.
    let matrix = ScenarioMatrix {
        seeds: (0..8).collect(),
        volatilities: vec![Volatility::Low],
        visibilities: vec![5 * MINUTE, 10 * MINUTE],
        cluster_machines: vec![4, 8],
        models: vec![
            DurationModel {
                mean_s: 60.0,
                cv: 0.3,
                ..Default::default()
            },
            DurationModel {
                mean_s: 120.0,
                cv: 0.3,
                ..Default::default()
            },
        ],
        ..Default::default()
    };
    let jobs = JobSpec::plate("P", 96, 4, vec![]); // 384 jobs per cell
    SweepPlan::new(cfg, jobs, matrix)
}

/// A 1000-scenario plan (10 machines × 10 visibilities × 10 means) with
/// a real Job file, rendered to a Sweep file — the declarative-layer
/// baseline: how fast a committed experiment file turns back into an
/// expanded scenario list.
fn plan_expansion_bench() {
    let plan = SweepPlan::builder()
        .jobs(JobSpec::plate("P", 24, 2, vec![]))
        .seeds([1])
        .machines((1..=10).map(|m| m * 2))
        .visibilities((1..=10).map(|v| v * MINUTE))
        .job_mean_s((1..=10).map(|s| s as f64 * 30.0))
        .build()
        .expect("bench plan");
    let text = SweepFile::render(&plan);
    let scenario_count = plan.matrix.scenarios().len();
    assert_eq!(scenario_count, 1000);
    println!(
        "\n== plan expansion: {}-byte Sweep file -> {} scenarios ==\n",
        text.len(),
        scenario_count
    );

    let iters = 50u32;
    let t0 = Instant::now();
    let mut expanded = 0usize;
    for _ in 0..iters {
        let parsed = SweepFile::from_text(&text)
            .expect("render must parse")
            .to_plan()
            .expect("file must plan");
        expanded += parsed.matrix.scenarios().len();
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(expanded, scenario_count * iters as usize);
    println!(
        "{iters} parse+expand iterations in {wall:.3}s  ({:.0} scenarios/s, {:.2} ms/iteration)",
        expanded as f64 / wall,
        wall * 1000.0 / f64::from(iters)
    );
}

/// Shard-count scaling over real worker processes.  Throughput is
/// simulated jobs per wall-clock second (cells × jobs/cell ÷ wall);
/// every shard count is cross-checked bit-identical against the
/// single-process engine before its number is reported.
fn sharded_bench(json: bool) {
    let plan = plan_64_cells();
    let jobs_total = (plan.matrix.cell_count() * plan.jobs.groups.len()) as f64;
    let reference = run_sweep(&plan, 2).expect("reference sweep failed");

    if !json {
        println!(
            "== sharded sweep: {} cells x {} jobs across real worker processes ==\n",
            plan.matrix.cell_count(),
            plan.jobs.groups.len()
        );
        println!("{:>7} {:>10} {:>12}", "shards", "wall s", "sim jobs/s");
    }
    let mut throughput = Value::obj();
    for &shards in &[1usize, 2, 4, 8] {
        let exec = ProcessExecutor::new(env!("CARGO_BIN_EXE_ds"), Duration::from_secs(600));
        let opts = ShardOptions {
            shards,
            threads: 2,
            retries: 0,
        };
        let t0 = Instant::now();
        let run = run_sweep_sharded(&plan, &opts, &exec).expect("sharded sweep failed");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(
            reference.report, run.report,
            "shard count changed the report — determinism broken"
        );
        let jobs_per_s = jobs_total / wall.max(1e-9);
        if json {
            throughput = throughput.with(&shards.to_string(), jobs_per_s);
        } else {
            println!("{shards:>7} {wall:>10.2} {jobs_per_s:>12.0}");
        }
    }
    if json {
        let out = Value::obj()
            .with("bench", "sweep")
            .with("mode", "shards")
            .with("cells", plan.matrix.cell_count())
            .with("jobs_per_cell", plan.jobs.groups.len())
            .with("shard_throughput", throughput);
        println!("{out}");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--shards") {
        sharded_bench(args.iter().any(|a| a == "--json"));
        return;
    }
    let plan = plan_64_cells();
    println!(
        "== sweep thread scaling: {} cells x {} jobs ==\n",
        plan.matrix.cell_count(),
        plan.jobs.groups.len()
    );
    println!("{:>7} {:>10} {:>9} {:>12}", "threads", "wall s", "speedup", "cells/s");

    let mut serial_wall = 0.0;
    let mut reference = None;
    for &threads in &[1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let run = run_sweep(&plan, threads).expect("sweep failed");
        let wall = t0.elapsed().as_secs_f64();
        if threads == 1 {
            serial_wall = wall;
        }
        match &reference {
            None => reference = Some(run.report.clone()),
            Some(r) => assert_eq!(
                *r, run.report,
                "thread count changed the report — determinism broken"
            ),
        }
        println!(
            "{threads:>7} {wall:>10.2} {:>8.2}x {:>12.1}",
            serial_wall / wall,
            run.cells.len() as f64 / wall
        );
    }
    println!("\ngate: speedup at 8 threads should be >= 3x (near-linear up to the core count).");

    plan_expansion_bench();
}
