//! Sweep-engine thread-scaling bench (acceptance gate: a 64-cell sweep
//! at 8 threads must beat 1 thread by >= 3x wall-clock).
//!
//!     cargo bench --bench sweep
//!
//! Each cell is an independent discrete-event simulation, so the engine
//! is embarrassingly parallel; the only serial parts are plan expansion
//! and the final aggregation.  The bench also cross-checks that every
//! thread count produced the bit-identical SweepReport — perf must never
//! buy nondeterminism.

use std::time::Instant;

use ds_rs::aws::ec2::Volatility;
use ds_rs::config::{AppConfig, JobSpec};
use ds_rs::coordinator::sweep::{run_sweep, ScenarioMatrix, SweepPlan};
use ds_rs::sim::MINUTE;
use ds_rs::workloads::DurationModel;

fn plan_64_cells() -> SweepPlan {
    let cfg = AppConfig {
        cluster_machines: 4,
        tasks_per_machine: 2,
        docker_cores: 2,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: 10 * MINUTE,
        ..Default::default()
    };
    // 8 seeds x (2 machines x 2 visibilities x 2 models) = 64 cells.
    let matrix = ScenarioMatrix {
        seeds: (0..8).collect(),
        volatilities: vec![Volatility::Low],
        visibilities: vec![5 * MINUTE, 10 * MINUTE],
        cluster_machines: vec![4, 8],
        models: vec![
            DurationModel {
                mean_s: 60.0,
                cv: 0.3,
                ..Default::default()
            },
            DurationModel {
                mean_s: 120.0,
                cv: 0.3,
                ..Default::default()
            },
        ],
        ..Default::default()
    };
    let jobs = JobSpec::plate("P", 96, 4, vec![]); // 384 jobs per cell
    SweepPlan::new(cfg, jobs, matrix)
}

fn main() {
    let plan = plan_64_cells();
    println!(
        "== sweep thread scaling: {} cells x {} jobs ==\n",
        plan.matrix.cell_count(),
        plan.jobs.groups.len()
    );
    println!("{:>7} {:>10} {:>9} {:>12}", "threads", "wall s", "speedup", "cells/s");

    let mut serial_wall = 0.0;
    let mut reference = None;
    for &threads in &[1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let run = run_sweep(&plan, threads).expect("sweep failed");
        let wall = t0.elapsed().as_secs_f64();
        if threads == 1 {
            serial_wall = wall;
        }
        match &reference {
            None => reference = Some(run.report.clone()),
            Some(r) => assert_eq!(
                *r, run.report,
                "thread count changed the report — determinism broken"
            ),
        }
        println!(
            "{threads:>7} {wall:>10.2} {:>8.2}x {:>12.1}",
            serial_wall / wall,
            run.cells.len() as f64 / wall
        );
    }
    println!("\ngate: speedup at 8 threads should be >= 3x (near-linear up to the core count).");
}
