//! PJRT workload latency bench (experiment K1): per-artifact compile and
//! execute timing through the real runtime.  Requires `make artifacts`.
//!
//!     cargo bench --bench runtime_exec

use std::time::Instant;

use ds_rs::runtime::PjrtRuntime;
use ds_rs::sim::SimRng;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("artifacts not built; run `make artifacts` first");
        return;
    }
    let mut rt = PjrtRuntime::new(dir).unwrap();
    let names: Vec<String> = rt.manifest().names().iter().map(|s| s.to_string()).collect();
    println!("== PJRT workload latency (N=50 runs each) ==\n");
    println!(
        "{:<24} {:>10} {:>12} {:>10} {:>10} {:>10} {:>12}",
        "workload", "in f32s", "compile ms", "mean ms", "p50 ms", "p95 ms", "Mpixel/s"
    );
    let mut rng = SimRng::new(1);
    for name in names {
        let info = rt.info(&name).unwrap().clone();
        let inputs: Vec<Vec<f32>> = info
            .input_lens()
            .iter()
            .map(|&n| (0..n).map(|_| rng.f64() as f32).collect())
            .collect();
        // First call compiles.
        let t0 = Instant::now();
        rt.ensure_compiled(&name).unwrap();
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        // Warmup.
        for _ in 0..3 {
            let _ = rt.execute(&name, &inputs).unwrap();
        }
        let mut times: Vec<f64> = (0..50)
            .map(|_| rt.execute(&name, &inputs).unwrap().1)
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let pixels: usize = info.input_lens().iter().sum();
        println!(
            "{:<24} {:>10} {:>12.0} {:>10.2} {:>10.2} {:>10.2} {:>12.1}",
            name,
            pixels,
            compile_ms,
            mean,
            percentile(&times, 0.5),
            percentile(&times, 0.95),
            pixels as f64 / (mean * 1e3), // Mpixel/s = pixels / (ms*1000)
        );
    }
    println!("\nNote: interpret-mode Pallas lowers to plain HLO; these CPU timings measure the artifact as shipped, not TPU performance (see DESIGN.md §Perf).");
}
