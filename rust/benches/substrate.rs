//! Substrate micro-benchmarks (hand-rolled harness: the image vendors no
//! criterion).  Measures the L3 hot-path primitives the perf pass
//! optimizes: SQS ops, event heap, market price generation, ECS
//! placement, S3 listing, JSON parsing.
//!
//!     cargo bench --bench substrate

use std::time::Instant;

use ds_rs::aws::ec2::{SpotMarket, Volatility};
use ds_rs::aws::ecs::{Ecs, Service, TaskDefinition};
use ds_rs::aws::s3::{Body, S3};
use ds_rs::aws::sqs::Sqs;
use ds_rs::json;
use ds_rs::sim::{EventQueue, MINUTE};

/// Run `f` `iters` times, print and return ns/op.
fn bench(name: &str, iters: u64, mut f: impl FnMut(u64)) -> f64 {
    for i in 0..(iters / 10).max(1) {
        f(i); // warmup
    }
    let t0 = Instant::now();
    for i in 0..iters {
        f(i);
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let throughput = 1e9 / ns;
    println!("{name:<46} {ns:>12.0} ns/op {throughput:>14.0} op/s");
    ns
}

fn main() {
    println!("== substrate micro-benchmarks ==\n");

    // SQS full cycle: send + receive + delete.
    {
        let mut sqs = Sqs::new();
        sqs.create_queue("q", 5 * MINUTE);
        bench("sqs send+receive+delete cycle", 200_000, |i| {
            sqs.send("q", "job-body", i).unwrap();
            let (_, h) = sqs.receive("q", i).unwrap().unwrap();
            sqs.delete("q", h, i).unwrap();
        });
    }

    // SQS receive from a deep queue (visibility bookkeeping).
    {
        let mut sqs = Sqs::new();
        sqs.create_queue("q", 5 * MINUTE);
        for i in 0..100_000u64 {
            sqs.send("q", format!("j{i}"), 0).unwrap();
        }
        bench("sqs receive (100k-deep queue)", 100_000, |i| {
            let _ = sqs.receive("q", i).unwrap();
        });
    }

    // Event heap: schedule + pop interleaved at 10k live events.
    {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at(i, i);
        }
        bench("event heap schedule+pop (10k live)", 1_000_000, |i| {
            let (t, _) = q.pop().unwrap();
            q.schedule_at(t + 10_000 + (i % 97), i);
        });
    }

    // Spot market: lazy path extension (per simulated minute of price).
    {
        let mut market = SpotMarket::new(7, Volatility::High);
        let mut t = 0u64;
        bench("spot market price_at (fresh minute)", 500_000, |_| {
            t += MINUTE;
            let _ = market.price_at("m5.xlarge", t);
        });
    }
    {
        let mut market = SpotMarket::new(7, Volatility::High);
        let _ = market.price_at("m5.xlarge", 1_000 * MINUTE);
        bench("spot market price_at (cached)", 1_000_000, |i| {
            let _ = market.price_at("m5.xlarge", (i % 1_000) * MINUTE);
        });
    }

    // ECS placement pass on a 64-instance cluster, service saturated.
    {
        let mut ecs = Ecs::new();
        ecs.register_task_definition(TaskDefinition {
            family: "app".into(),
            cpu_shares: 2048,
            memory_mb: 7_500,
            env: vec![],
        });
        ecs.create_service(Service {
            name: "svc".into(),
            cluster: "default".into(),
            task_family: "app".into(),
            desired_count: 128,
        })
        .unwrap();
        for i in 0..64u64 {
            ecs.register_instance("default", i, 4, 16_384).unwrap();
        }
        let placed = ecs.place_tasks(0);
        assert_eq!(placed.len(), 128);
        bench("ecs place_tasks no-op pass (64in/128ctr)", 20_000, |i| {
            let _ = ecs.place_tasks(i);
        });
    }

    // S3: put synthetic + list a 10k-object prefix.
    {
        let mut s3 = S3::new();
        s3.create_bucket("b");
        for i in 0..10_000u64 {
            s3.put("b", &format!("out/{i:06}.csv"), Body::Synthetic { size: 100 }, 0)
                .unwrap();
        }
        bench("s3 list_prefix (narrow, 10k objects)", 100_000, |i| {
            let _ = s3.list_prefix("b", &format!("out/{:06}", i % 10_000));
        });
        bench("s3 put synthetic", 200_000, |i| {
            s3.put("b", "hot/key", Body::Synthetic { size: 100 }, i).unwrap();
        });
    }

    // JSON: parse a typical job message.
    {
        let msg = r#"{"input_prefix": "input", "output_prefix": "output",
            "output_bucket": "ds-data", "pipeline": "segment.cppipe",
            "Metadata_Plate": "BR00117010", "Metadata_Well": "C07",
            "Metadata_Site": 3}"#;
        bench("json parse job message", 200_000, |_| {
            let _ = json::parse(msg).unwrap();
        });
    }

    println!("\ndone.");
}
