//! Transfer-scheduler throughput: plan/poll events per second vs
//! concurrent-flow count.
//!
//!     cargo bench --bench dataplane
//!
//! Every flow start/finish re-plans every rate (max-min progressive
//! filling is O(links × flows) per boundary), so the interesting number
//! is how event throughput degrades as the concurrent-flow population
//! grows.  The run driver keeps populations in the tens-to-hundreds
//! (cores × machines); this bench sweeps well past that.

use std::time::Instant;

use ds_rs::aws::s3::dataplane::{DataPlane, Direction, NetProfile};

fn episode(flows: usize) -> (u64, u64) {
    let mut plane = DataPlane::new(NetProfile::standard());
    let mut events: u64 = 0;
    // Staggered arrivals: 4 flows per instance, alternating directions,
    // two buckets, 8 MB each — a busy mid-run fleet in miniature.
    for i in 0..flows {
        plane.start(
            i as u64,
            (i / 4) as u64,
            1.25,
            if i % 2 == 0 { "data" } else { "logs" },
            if i % 3 == 0 { Direction::Upload } else { Direction::Download },
            8_000_000,
        );
        events += 1;
    }
    while let Some(t) = plane.next_event() {
        events += 1 + plane.poll(t).len() as u64;
    }
    let st = plane.stats();
    assert_eq!(st.flows_completed, flows as u64, "bench must drain");
    (events, st.bytes_downloaded + st.bytes_uploaded)
}

fn main() {
    println!(
        "{:>8} {:>10} {:>12} {:>12} {:>14}",
        "flows", "events", "wall ms", "events/s", "GB moved"
    );
    for &flows in &[8usize, 32, 128, 512] {
        // Warm-up pass, then the measured one.
        let _ = episode(flows);
        let t0 = Instant::now();
        let (events, bytes) = episode(flows);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:>8} {:>10} {:>12.2} {:>12.0} {:>14.2}",
            flows,
            events,
            wall * 1e3,
            events as f64 / wall.max(1e-9),
            bytes as f64 / 1e9
        );
    }
}
