//! Event-core throughput: queue backends, store backends, and the
//! macro-scale simulation (the million-job number).
//!
//!     cargo bench --bench event_core                  # micro + smoke macro
//!     cargo bench --bench event_core -- --million     # the full 10⁶-job run
//!     cargo bench --bench event_core -- --json        # machine-readable line
//!
//! `benchmark_compare.sh` at the repo root drives the `--json` mode and
//! diffs the output against the committed `BENCH_*.json` snapshot; the
//! CI bench lane fails on a >20% throughput regression.

use std::time::Instant;

use ds_rs::config::{FleetSpec, JobSpec};
use ds_rs::coordinator::run::{run_full, RunOptions};
use ds_rs::json::Value;
use ds_rs::sim::{EventQueue, IdStore, QueueKind, SimRng, StoreKind};
use ds_rs::testutil::fixtures::{modeled, quick_cfg};

/// Hold-one-pop-one churn at a steady population of `n`: the DES access
/// pattern.  Returns operations (pushes + pops) per second.
fn queue_churn(kind: QueueKind, n: usize, ops: usize) -> f64 {
    let mut q = EventQueue::with_kind(kind);
    let mut rng = SimRng::new(0xBEEF);
    for _ in 0..n {
        q.schedule_in(rng.below(60_000), 0u64);
    }
    let t0 = Instant::now();
    for _ in 0..ops {
        let (_, e) = q.pop().expect("steady population");
        q.schedule_in(rng.below(60_000), e + 1);
    }
    let wall = t0.elapsed().as_secs_f64();
    std::hint::black_box(q.len());
    (ops * 2) as f64 / wall.max(1e-9)
}

/// Random lookups over `n` sequential ids.  Returns lookups per second.
fn store_churn(kind: StoreKind, n: u64, ops: u64) -> f64 {
    let mut s: IdStore<u64> = IdStore::with_kind(kind);
    for id in 1..=n {
        s.insert(id, id * 3);
    }
    let mut rng = SimRng::new(0xFEED);
    let t0 = Instant::now();
    let mut acc = 0u64;
    for _ in 0..ops {
        let id = 1 + rng.below(n);
        acc = acc.wrapping_add(*s.get(id).expect("id in range"));
    }
    let wall = t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    ops as f64 / wall.max(1e-9)
}

struct MacroResult {
    jobs: u64,
    machines: u32,
    wall_s: f64,
    events: u64,
    jobs_per_s: f64,
    events_per_s: f64,
}

/// The full simulation at scale: `wells × sites` jobs on `machines`
/// on-demand machines, default engine (calendar + dense stores).
fn macro_run(wells: u32, sites: u32, machines: u32) -> MacroResult {
    let mut cfg = quick_cfg(machines);
    cfg.check_if_done.enabled = false;
    let jobs = JobSpec::plate("P", wells, sites, vec![]);
    let mut fleet = FleetSpec::template("us-east-1").expect("builtin fleet");
    fleet.on_demand_base = machines;
    let mut ex = modeled(60.0);
    let t0 = Instant::now();
    let report = run_full(&cfg, &jobs, &fleet, &mut ex, RunOptions::default())
        .expect("macro bench run");
    let wall = t0.elapsed().as_secs_f64();
    let jobs_n = u64::from(wells) * u64::from(sites);
    assert_eq!(report.stats.completed, jobs_n, "bench must complete all jobs");
    MacroResult {
        jobs: jobs_n,
        machines,
        wall_s: wall,
        events: report.stats.events_processed,
        jobs_per_s: jobs_n as f64 / wall.max(1e-9),
        events_per_s: report.stats.events_processed as f64 / wall.max(1e-9),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json = args.iter().any(|a| a == "--json");
    let million = args.iter().any(|a| a == "--million");

    // Micro: queue backends at DES-typical populations.
    const QUEUE_OPS: usize = 400_000;
    let heap_qps = queue_churn(QueueKind::Heap, 4_096, QUEUE_OPS);
    let calendar_qps = queue_churn(QueueKind::Calendar, 4_096, QUEUE_OPS);

    // Micro: store backends at fleet-typical id counts.
    const STORE_OPS: u64 = 2_000_000;
    let map_lps = store_churn(StoreKind::Map, 4_096, STORE_OPS);
    let dense_lps = store_churn(StoreKind::Dense, 4_096, STORE_OPS);

    // Macro: smoke = 10⁵ jobs / 500 machines; --million = the real thing.
    let mac = if million {
        macro_run(1_000, 1_000, 1_000)
    } else {
        macro_run(500, 200, 500)
    };

    if json {
        let out = Value::obj()
            .with("bench", "event_core")
            .with("mode", if million { "million" } else { "smoke" })
            .with(
                "queue_ops_per_s",
                Value::obj()
                    .with("heap", heap_qps)
                    .with("calendar", calendar_qps),
            )
            .with(
                "store_lookups_per_s",
                Value::obj().with("map", map_lps).with("dense", dense_lps),
            )
            .with(
                "macro",
                Value::obj()
                    .with("jobs", mac.jobs)
                    .with("machines", mac.machines)
                    .with("wall_s", mac.wall_s)
                    .with("events", mac.events)
                    .with("jobs_per_s", mac.jobs_per_s)
                    .with("events_per_s", mac.events_per_s),
            );
        println!("{out}");
        return;
    }

    println!("queue churn @ 4096 live events ({QUEUE_OPS} op pairs):");
    println!("  {:>10} {:>14.0} ops/s", "heap", heap_qps);
    println!("  {:>10} {:>14.0} ops/s", "calendar", calendar_qps);
    println!("store lookups @ 4096 ids ({STORE_OPS} lookups):");
    println!("  {:>10} {:>14.0} lookups/s", "map", map_lps);
    println!("  {:>10} {:>14.0} lookups/s", "dense", dense_lps);
    println!(
        "macro ({} jobs / {} machines): {:.2} s wall, {} events, {:.0} jobs/s, {:.0} events/s",
        mac.jobs, mac.machines, mac.wall_s, mac.events, mac.jobs_per_s, mac.events_per_s
    );
}
