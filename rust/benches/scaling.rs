//! End-to-end simulator throughput bench (backs experiment T1 and the L3
//! perf targets): how fast does the coordinator push simulated work?
//!
//!     cargo bench --bench scaling

use std::time::Instant;

use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{RunOptions, Simulation};
use ds_rs::sim::MINUTE;
use ds_rs::workloads::{DurationModel, ModeledExecutor};

fn run_one(machines: u32, jobs_n: u32) -> (f64, u64, u64) {
    let cfg = AppConfig {
        cluster_machines: machines,
        tasks_per_machine: 2,
        docker_cores: 2,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: 10 * MINUTE,
        ..Default::default()
    };
    let jobs = JobSpec::plate("P", jobs_n, 4, vec![]);
    let mut sim = Simulation::new(cfg, RunOptions::default()).unwrap();
    sim.submit(&jobs).unwrap();
    sim.start(&FleetSpec::template("us-east-1").unwrap()).unwrap();
    let mut ex = ModeledExecutor {
        model: DurationModel {
            mean_s: 90.0,
            cv: 0.3,
            ..Default::default()
        },
        ..Default::default()
    };
    let t0 = Instant::now();
    let report = sim.run(&mut ex).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(
        report.stats.completed + report.stats.skipped_done,
        u64::from(jobs_n) * 4
    );
    (wall, report.stats.events_processed, report.ended_at)
}

fn main() {
    println!("== coordinator end-to-end simulation throughput ==\n");
    println!(
        "{:>8} {:>8} {:>10} {:>12} {:>14} {:>16}",
        "machines", "jobs", "wall s", "events", "events/s", "sim-min/wall-s"
    );
    for &(machines, jobs) in &[(4u32, 96u32), (16, 96), (64, 96), (16, 384), (64, 384), (128, 384)]
    {
        // jobs param = wells; 4 sites each.
        let (wall, events, ended) = run_one(machines, jobs);
        println!(
            "{:>8} {:>8} {:>10.3} {:>12} {:>14.0} {:>16.0}",
            machines,
            jobs * 4,
            wall,
            events,
            events as f64 / wall,
            (ended as f64 / MINUTE as f64) / wall
        );
    }
    println!("\nL3 target: the coordinator must never be the bottleneck — events/s should sit in the millions (each event is one SQS/ECS/EC2 interaction).");
}
