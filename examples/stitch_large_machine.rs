//! Distributed-Fiji scenario: "a large machine to perform a single task
//! on many images (such as stitching)" — one m5.12xlarge stitching 3x3
//! tile grids with the real PJRT stitch pipeline.
//!
//!     make artifacts && cargo run --release --example stitch_large_machine

use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{RunOptions, Simulation};
use ds_rs::json::Value;
use ds_rs::runtime::PjrtRuntime;
use ds_rs::sim::MINUTE;
use ds_rs::workloads::synth::bytes_to_f32;
use ds_rs::workloads::PjrtExecutor;

const MONTAGES: usize = 6;
const WORKLOAD: &str = "stitch_g3_t128_o16";

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("== Distributed-Fiji: one 48-vCPU machine stitching {MONTAGES} montages (3x3 tiles) ==\n");

    let mut cfg = AppConfig {
        app_name: "FijiStitch".into(),
        workload_id: WORKLOAD.into(),
        cluster_machines: 1,
        tasks_per_machine: 1,
        docker_cores: 1,
        machine_types: vec!["m5.12xlarge".into()],
        machine_price: 1.20,
        cpu_shares: 48 * 1024,
        memory_mb: 180_000,
        sqs_message_visibility: 30 * MINUTE,
        sqs_queue_name: "stitch-q".into(),
        sqs_dead_letter_queue: "stitch-dlq".into(),
        ..Default::default()
    };
    cfg.check_if_done.expected_number_files = 2; // montage + scores

    let jobs = JobSpec {
        shared: vec![("output_prefix".into(), Value::from("montages"))],
        groups: (0..MONTAGES)
            .map(|i| vec![("Metadata_Montage".to_string(), Value::Str(format!("M{i}")))])
            .collect(),
    };

    let mut sim = Simulation::new(cfg.clone(), RunOptions::default())?;
    sim.submit(&jobs)?;
    sim.start(&FleetSpec::template("us-east-1").unwrap())?;

    let runtime = PjrtRuntime::new(&artifacts)?;
    let mut executor = PjrtExecutor::new(runtime, WORKLOAD)?;
    executor.time_scale = 2_000.0; // stitching jobs are long
    let report = sim.run(&mut executor)?;

    println!("{}", report.summary());
    assert_eq!(report.stats.completed, MONTAGES as u64);
    // One machine did all the work sequentially (the fleet may launch one
    // short-lived replacement in the minute between the worker's
    // self-shutdown and the monitor's cleanup — the paper's normal churn).
    assert!(report.stats.instances_launched <= 2);

    // Inspect montage 0: seam quality and dimensions.
    let side = 3 * 128 - 2 * 16;
    let montage = sim
        .acct
        .s3
        .get("ds-data", &format!("montages/M0/montage_{side}x{side}.f32"))?;
    let px = bytes_to_f32(montage.body.bytes().unwrap());
    assert_eq!(px.len(), side * side);
    let scores_obj = sim.acct.s3.get("ds-data", "montages/M0/seam_scores.csv")?;
    let csv = std::str::from_utf8(scores_obj.body.bytes().unwrap())?.to_string();
    let nccs: Vec<f32> = csv
        .lines()
        .skip(1)
        .map(|l| l.split(',').nth(1).unwrap().parse().unwrap())
        .collect();
    let mean_ncc = nccs.iter().sum::<f32>() / nccs.len() as f32;
    println!(
        "\nmontage M0: {side}x{side} px, pixel range [{:.3}, {:.3}], {} seams, mean NCC {:.3}",
        px.iter().cloned().fold(f32::INFINITY, f32::min),
        px.iter().cloned().fold(0.0, f32::max),
        nccs.len(),
        mean_ncc
    );
    assert!(mean_ncc > 0.8, "seams should register cleanly");
    println!("OK: large-machine single-task pattern works end to end.");
    Ok(())
}
