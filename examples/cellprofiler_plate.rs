//! End-to-end driver with REAL compute (experiment E2E).
//!
//! A full Distributed-CellProfiler-style run where every job executes the
//! AOT-compiled XLA feature-extraction pipeline through PJRT — Python
//! never runs.  The workload: a 96-well plate, 4 sites per well (384
//! jobs), synthetic microscopy fields staged in simulated S3, feature
//! CSVs written back.  Reports real per-job latency, throughput, feature
//! sanity, and the cost model.  Results recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example cellprofiler_plate

use std::time::Instant;

use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{RunOptions, Simulation};
use ds_rs::runtime::PjrtRuntime;
use ds_rs::sim::clock::fmt_dur;
use ds_rs::sim::MINUTE;
use ds_rs::workloads::drivers::CP_FEATURE_NAMES;
use ds_rs::workloads::synth::{f32_to_bytes, image_seed, SynthImage};
use ds_rs::workloads::PjrtExecutor;

const WELLS: u32 = 96;
const SITES: u32 = 4;
const WORKLOAD: &str = "cp_128_b1";
const IMG: usize = 128;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("== Distributed-CellProfiler end-to-end: {WELLS} wells x {SITES} sites, real PJRT compute ==\n");

    let cfg = AppConfig {
        app_name: "CPPlate".into(),
        workload_id: WORKLOAD.into(),
        cluster_machines: 8,
        tasks_per_machine: 2,
        docker_cores: 2,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: 10 * MINUTE,
        ..Default::default()
    };
    let jobs = JobSpec::plate("BR00117010", WELLS, SITES, vec![]);
    let fleet_file = FleetSpec::template("us-east-1").unwrap();

    let mut sim = Simulation::new(cfg.clone(), RunOptions::default())?;

    // Stage real input images into S3 (half the jobs; the other half
    // exercises the fetch-or-synthesize fallback — both paths run the
    // same pipeline).
    let gen = SynthImage {
        size: IMG,
        n_blobs: 20,
        ..Default::default()
    };
    let t_stage = Instant::now();
    let mut staged = 0u32;
    sim.stage(|acct| {
        for (i, m) in jobs.to_messages().iter().enumerate() {
            if i % 2 != 0 {
                continue;
            }
            let msg = ds_rs::json::parse(m).unwrap();
            let tag = ds_rs::workloads::drivers::job_tag(&msg);
            let plate = msg.get("Metadata_Plate").unwrap().as_str().unwrap();
            let well = msg.get("Metadata_Well").unwrap().as_str().unwrap();
            let site = msg.get("Metadata_Site").unwrap().as_u64().unwrap();
            let img = gen.render(image_seed(plate, well, site));
            acct.s3
                .put(
                    "ds-data",
                    &format!("input/{tag}.f32"),
                    ds_rs::aws::s3::Body::Bytes(f32_to_bytes(&img)),
                    0,
                )
                .unwrap();
            staged += 1;
        }
    });
    println!(
        "staged {staged} input images ({:.1} MB) in {:.2}s wall",
        f64::from(staged) * (IMG * IMG * 4) as f64 / 1e6,
        t_stage.elapsed().as_secs_f64()
    );

    sim.submit(&jobs)?;
    sim.start(&fleet_file)?;

    let runtime = PjrtRuntime::new(&artifacts)?;
    let mut executor = PjrtExecutor::new(runtime, WORKLOAD)?;
    // Real CellProfiler jobs take minutes; our kernel takes milliseconds.
    // Scale measured wall time 1000x when charging the simulated clock so
    // coordination dynamics (visibility timeouts, alarms) stay realistic.
    executor.time_scale = 1_000.0;

    let wall = Instant::now();
    let report = sim.run(&mut executor)?;
    let wall_s = wall.elapsed().as_secs_f64();

    println!("\n{}", report.summary());
    let (compile_ms, execs, total_ms) = executor.runtime.stats(WORKLOAD).unwrap();
    println!("PJRT: compiled once in {compile_ms:.0} ms; {execs} executions, mean {:.2} ms/job, wall {:.1}s total",
        total_ms / execs as f64, wall_s);

    // Feature sanity over all outputs.
    let outputs = sim.acct.s3.list_prefix("ds-data", "output/");
    let mut fg_means = Vec::new();
    let mut count_proxies = Vec::new();
    let fg_i = CP_FEATURE_NAMES.iter().position(|f| *f == "fg_mean").unwrap();
    let cp_i = CP_FEATURE_NAMES
        .iter()
        .position(|f| *f == "object_count_proxy")
        .unwrap();
    for (key, _) in &outputs {
        let obj = sim.acct.s3.get("ds-data", key).unwrap();
        let csv = std::str::from_utf8(obj.body.bytes().unwrap()).unwrap();
        for line in csv.lines().skip(1) {
            let vals: Vec<f64> = line
                .split(',')
                .skip(1)
                .map(|v| v.parse().unwrap())
                .collect();
            fg_means.push(vals[fg_i]);
            count_proxies.push(vals[cp_i]);
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\nmeasurements: {} feature rows; fg_mean avg {:.4}; object-count proxy avg {:.1} (generator plants ~20 blobs)",
        fg_means.len(),
        mean(&fg_means),
        mean(&count_proxies),
    );
    println!(
        "makespan {} simulated; effective throughput {:.0} jobs/simulated-hour",
        fmt_dur(report.drained_at.unwrap()),
        report.jobs_per_hour()
    );
    assert_eq!(report.stats.completed, u64::from(WELLS * SITES));
    assert!(report.cleaned_up);
    println!("\nOK: all {} jobs completed with real compute, resources torn down.", WELLS * SITES);
    Ok(())
}
