//! Distributed-OmeZarrCreator scenario: convert a directory of images
//! into chunked multiscale zarr-like stores, with real PJRT pyramids.
//!
//!     make artifacts && cargo run --release --example zarr_conversion

use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{RunOptions, Simulation};
use ds_rs::json::Value;
use ds_rs::runtime::PjrtRuntime;
use ds_rs::sim::MINUTE;
use ds_rs::workloads::{zarr, PjrtExecutor};

const IMAGES: usize = 24;
const WORKLOAD: &str = "pyramid_256_l4";

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("== Distributed-OmeZarrCreator: {IMAGES} images -> .ome.zarr-shaped stores ==\n");

    // Each store: 22 chunks + 4 .zarray + 1 .zattrs = 27 objects; that is
    // exactly what CHECK_IF_DONE should expect.
    let levels = zarr::pyramid_levels(256, 256, 4);
    let expected = zarr::expected_objects(&levels) as u32;
    println!("per-store objects: {expected} (chunks {} + metadata {})",
        levels.iter().map(zarr::chunk_count).sum::<usize>(), levels.len() + 1);

    let mut cfg = AppConfig {
        app_name: "OmeZarr".into(),
        workload_id: WORKLOAD.into(),
        cluster_machines: 4,
        tasks_per_machine: 2,
        docker_cores: 1,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: 10 * MINUTE,
        sqs_queue_name: "zarr-q".into(),
        sqs_dead_letter_queue: "zarr-dlq".into(),
        ..Default::default()
    };
    cfg.check_if_done.expected_number_files = expected;

    let jobs = JobSpec {
        shared: vec![
            ("output_prefix".into(), Value::from("converted")),
            ("output_bucket".into(), Value::from("ds-data")),
        ],
        groups: (0..IMAGES)
            .map(|i| vec![("Metadata_Image".to_string(), Value::Str(format!("img{i:03}")))])
            .collect(),
    };

    let mut sim = Simulation::new(cfg.clone(), RunOptions::default())?;
    sim.submit(&jobs)?;
    sim.start(&FleetSpec::template("us-east-1").unwrap())?;

    let runtime = PjrtRuntime::new(&artifacts)?;
    let mut executor = PjrtExecutor::new(runtime, WORKLOAD)?;
    executor.time_scale = 1_000.0;
    let report = sim.run(&mut executor)?;

    println!("{}", report.summary());
    assert_eq!(report.stats.completed, IMAGES as u64);

    // Verify every store is complete and FAIR-shaped.
    let mut total_objects = 0;
    for i in 0..IMAGES {
        let store = format!("converted/img{i:03}/image.zarr");
        let objs = sim.acct.s3.list_prefix("ds-data", &store);
        assert_eq!(objs.len(), expected as usize, "{store}");
        total_objects += objs.len();
    }
    // Multiscales metadata parses and lists 4 datasets.
    let attrs = sim
        .acct
        .s3
        .get("ds-data", "converted/img000/image.zarr/.zattrs")?;
    let v = ds_rs::json::parse(std::str::from_utf8(attrs.body.bytes().unwrap())?)?;
    let datasets = v.get("multiscales").unwrap().as_arr().unwrap()[0]
        .get("datasets")
        .unwrap()
        .as_arr()
        .unwrap()
        .len();
    println!(
        "\nstores: {IMAGES} complete ({total_objects} objects, {datasets} scale levels each)"
    );
    println!(
        "rerunning the same Job file would skip everything via CHECK_IF_DONE (EXPECTED_NUMBER_FILES={expected})."
    );
    Ok(())
}
