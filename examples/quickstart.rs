//! Quickstart — Figure 1 end to end (experiment F1).
//!
//! The paper's whole pitch in one binary: edit two human-readable files
//! (we build them in code and print them), then run four single-line
//! commands that coordinate five AWS services.  Everything below runs on
//! the simulated account; swap in `--pjrt` via the `ds` CLI for real
//! compute.
//!
//!     cargo run --release --example quickstart

use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::run::{RunOptions, Simulation};
use ds_rs::sim::clock::fmt_dur;
use ds_rs::sim::MINUTE;
use ds_rs::workloads::{DurationModel, ModeledExecutor};

fn main() -> anyhow::Result<()> {
    println!("══════════════════════════════════════════════════════════════");
    println!(" Distributed-Something quickstart: 96-well plate, 4 sites/well");
    println!("══════════════════════════════════════════════════════════════\n");

    // ---- The two files you edit per run (paper: "two human-readable
    // files must be edited to configure individual DS runs") ------------
    let cfg = AppConfig {
        app_name: "NuclearSegmentation_Drosophila".into(),
        workload_id: "cp_256_b1".into(),
        cluster_machines: 24,
        tasks_per_machine: 2,
        docker_cores: 2,
        machine_types: vec!["m5.xlarge".into(), "c5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: 8 * MINUTE,
        sqs_queue_name: "nucseg-queue".into(),
        sqs_dead_letter_queue: "nucseg-dlq".into(),
        log_group_name: "nucseg".into(),
        ..Default::default()
    };
    println!("── Config file (config.py analog) ──");
    println!("{}\n", cfg.to_json().pretty());

    let jobs = JobSpec::plate("BR00117010", 96, 4, vec![]);
    println!(
        "── Job file: plate BR00117010, {} groups (96 wells x 4 sites) ──\n",
        jobs.groups.len()
    );

    // The Fleet file: account-specific, created once.
    let fleet_file = FleetSpec::template("us-east-1").unwrap();

    // ---- Command 1: python run.py setup --------------------------------
    println!("$ ds setup          # task definition + SQS queue/DLQ + ECS service");
    let mut sim = Simulation::new(cfg.clone(), RunOptions::default())?;
    println!("  ✓ task definition '{}' registered", cfg.task_family());
    println!(
        "  ✓ queue '{}' (+ DLQ '{}') created",
        cfg.sqs_queue_name, cfg.sqs_dead_letter_queue
    );
    println!("  ✓ service '{}' wants {} Dockers\n", cfg.service_name(),
        cfg.cluster_machines * cfg.tasks_per_machine);

    // ---- Command 2: python run.py submitJob ----------------------------
    println!("$ ds submit-job     # one SQS message per group");
    let n = sim.submit(&jobs)?;
    println!("  ✓ {n} jobs enqueued\n");

    // ---- Command 3: python run.py startCluster -------------------------
    println!("$ ds start-cluster  # spot fleet request + log groups");
    sim.start(&fleet_file)?;
    println!(
        "  ✓ spot fleet requested: {} machines from {:?} at ≤${}/h",
        cfg.cluster_machines, cfg.machine_types, cfg.machine_price
    );
    println!("  ✓ log groups '{}' and '{}' created\n", cfg.log_group_name,
        cfg.instance_log_group());

    // ---- Command 4: python run.py monitor (runs inside the event loop) -
    println!("$ ds monitor        # poll queue, reap alarms, clean up at zero\n");
    println!("── event loop running (simulated time) ──");
    let mut executor = ModeledExecutor {
        model: DurationModel {
            mean_s: 90.0, // a typical CellProfiler site takes ~1.5 min
            cv: 0.3,
            ..Default::default()
        },
        ..Default::default()
    };
    let report = sim.run(&mut executor)?;

    // ---- What happened --------------------------------------------------
    println!("{}", report.summary());
    println!("Figure-1 checklist:");
    println!(
        "  S3         {} output objects + {} exported log objects",
        sim.acct.s3.list_prefix("ds-data", "output/").len(),
        sim.acct.s3.list_prefix("ds-data", "exportedlogs/").len()
    );
    println!(
        "  SQS        queue deleted: {}; DLQ empty: {}",
        !sim.acct.sqs.queue_exists(&cfg.sqs_queue_name),
        sim.acct
            .sqs
            .approximate_counts(&cfg.sqs_dead_letter_queue, report.ended_at)
            == (0, 0)
    );
    println!(
        "  EC2        {} instances launched, all terminated: {}",
        report.stats.instances_launched,
        sim.acct.ec2.all_instances().iter().all(|i| !i.is_active())
    );
    println!(
        "  ECS        clean (no service, no task def, no containers): {}",
        sim.acct.ecs.is_clean(&cfg.service_name(), &cfg.task_family())
    );
    println!(
        "  CloudWatch {} metric datapoints published, alarms left: {}",
        sim.acct.metrics.put_count(),
        sim.acct.alarms.len()
    );
    println!(
        "\nDone: {} jobs in {} of simulated time for ${:.2}.",
        report.stats.completed,
        fmt_dur(report.drained_at.unwrap_or(report.ended_at)),
        report.cost.total_usd()
    );
    Ok(())
}
