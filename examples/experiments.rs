//! Experiment harness: regenerates every table in DESIGN.md §4 (T1–T17).
//!
//!     cargo run --release --example experiments [t1 t2 … | all]
//!
//! Each experiment prints the table DESIGN.md records.  All runs use
//! modeled job durations so hundreds of cluster-hours simulate in
//! seconds, deterministically.  The single-axis studies (T1 scaling, T4
//! visibility, T5 volatility) and the T12 allocation-strategy grid run
//! through the parallel sweep engine (`coordinator::sweep`), replicated
//! over several seeds, so the tables report cross-seed mean/p50/p95
//! instead of one arbitrary seed's draw.

use ds_rs::aws::ec2::Volatility;
use ds_rs::aws::s3::dataplane::NetProfile;
use ds_rs::config::{AppConfig, FleetSpec, JobSpec};
use ds_rs::coordinator::autoscale::ScalingPolicy;
use ds_rs::coordinator::run::{run_full, RunOptions, Simulation};
use ds_rs::coordinator::sweep::{default_threads, run_sweep, ScenarioMatrix, SweepPlan};
use ds_rs::json::Value;
use ds_rs::metrics::{Aggregate, RunReport, ScenarioSummary, SweepReport, Table};
use ds_rs::sim::clock::{fmt_dur, SimTime};
use ds_rs::sim::{HOUR, MINUTE, SECOND};
use ds_rs::workloads::{DurationModel, ModeledExecutor};

/// Zip a hand-labelled axis against a sweep's scenario summaries,
/// asserting the lengths line up so a matrix edit can never silently
/// mislabel rows.
fn labelled<'a, A>(
    axis: &'a [A],
    report: &'a SweepReport,
) -> impl Iterator<Item = (&'a A, &'a ScenarioSummary)> {
    assert_eq!(
        axis.len(),
        report.scenarios.len(),
        "axis labels out of sync with the scenario matrix"
    );
    axis.iter().zip(&report.scenarios)
}

/// Run a matrix over the default fleet and return its aggregation; the
/// cells run in parallel but the report is bit-identical at any thread
/// count.
fn sweep_report(
    base: AppConfig,
    jobs: JobSpec,
    matrix: ScenarioMatrix,
    opts: RunOptions,
) -> SweepReport {
    let mut plan = SweepPlan::new(base, jobs, matrix);
    plan.base_opts = opts;
    run_sweep(&plan, default_threads()).expect("sweep failed").report
}

fn cfg(machines: u32, visibility: SimTime) -> AppConfig {
    AppConfig {
        cluster_machines: machines,
        tasks_per_machine: 2,
        docker_cores: 2,
        machine_types: vec!["m5.xlarge".into()],
        machine_price: 0.10,
        sqs_message_visibility: visibility,
        ..Default::default()
    }
}

fn fleet_file() -> FleetSpec {
    FleetSpec::template("us-east-1").unwrap()
}

fn run(
    c: &AppConfig,
    jobs: &JobSpec,
    model: DurationModel,
    opts: RunOptions,
) -> RunReport {
    let mut ex = ModeledExecutor {
        model,
        ..Default::default()
    };
    run_full(c, jobs, &fleet_file(), &mut ex, opts).expect("run failed")
}

fn model(mean_s: f64) -> DurationModel {
    DurationModel {
        mean_s,
        cv: 0.3,
        ..Default::default()
    }
}

/// T1 — scaling: jobs/hour vs CLUSTER_MACHINES, 3 seeds per point,
/// driven through the sweep engine.
fn t1() {
    println!("\n== T1: throughput vs cluster size (2000 jobs, 90 s mean, 3 seeds) ==");
    let machine_axis = vec![1u32, 2, 4, 8, 16, 32, 64, 128];
    let matrix = ScenarioMatrix {
        seeds: vec![42, 43, 44],
        cluster_machines: machine_axis.clone(),
        models: vec![model(90.0)],
        ..Default::default()
    };
    let jobs = JobSpec::plate("P", 96, 21, vec![]); // 2016 jobs
    let report = sweep_report(cfg(1, 10 * MINUTE), jobs, matrix, RunOptions::default());
    let mut table = Table::new(&[
        "machines", "cores", "drained", "makespan p50", "makespan p95", "jobs/h", "ideal jobs/h", "efficiency",
    ]);
    // Scenario order follows the machines axis (the only multi-value axis).
    for (m, s) in labelled(&machine_axis, &report) {
        let cores = m * 4;
        let ideal = f64::from(cores) * 3600.0 / 90.0;
        table.row(&[
            m.to_string(),
            cores.to_string(),
            format!("{}/{}", s.drained, s.cells),
            s.makespan_cell(s.makespan_s.p50),
            s.makespan_cell(s.makespan_s.p95),
            format!("{:.0}", s.jobs_per_hour.mean),
            format!("{ideal:.0}"),
            format!("{:.2}", s.jobs_per_hour.mean / ideal),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: near-linear until the 2016-job queue drains faster than boot+tail overhead.");
}

/// T2 — cost: spot vs on-demand equivalent, and bid sweep.
fn t2() {
    println!("\n== T2: spot vs on-demand cost (384 jobs, 8 machines) ==");
    let jobs = JobSpec::plate("P", 96, 4, vec![]);
    let c = cfg(8, 10 * MINUTE);
    let r = run(&c, &jobs, model(90.0), RunOptions::default());
    println!(
        "machine-hours {:.2}  spot ${:.4}  on-demand ${:.4}  savings {:.1}x  overhead {:.2}%",
        r.cost.machine_hours,
        r.cost.ec2_usd,
        r.cost.on_demand_equivalent_usd,
        r.cost.spot_savings_factor(),
        r.cost.overhead_fraction() * 100.0
    );

    println!("\nbid sweep (medium volatility): cost and makespan vs MACHINE_PRICE");
    let base = 0.192 * 0.30;
    let mut table = Table::new(&["bid $/h", "bid/base", "makespan", "interruptions", "EC2 $"]);
    for &mult in &[1.05, 1.2, 1.5, 2.0, 3.0] {
        let mut c = cfg(8, 10 * MINUTE);
        c.machine_price = base * mult;
        let r = run(
            &c,
            &jobs,
            model(90.0),
            RunOptions {
                volatility: Volatility::Medium,
                seed: 21,
                max_sim_time: 3 * 24 * HOUR,
                ..Default::default()
            },
        );
        table.row(&[
            format!("{:.3}", base * mult),
            format!("{mult:.2}"),
            r.makespan().map(fmt_dur).unwrap_or("-".into()),
            r.stats.interruptions.to_string(),
            format!("{:.4}", r.cost.ec2_usd),
        ]);
    }
    println!("{}", table.render());
}

/// T3 — cheapest mode vs normal monitor.
fn t3() {
    println!("\n== T3: cheapest mode (192 jobs, 6 machines, 120 s mean) ==");
    let jobs = JobSpec::plate("P", 48, 4, vec![]);
    let c = cfg(6, 10 * MINUTE);
    let mut table = Table::new(&["mode", "makespan", "EC2 $", "total $", "instances"]);
    for (name, cheapest, crash) in [
        ("normal", false, None),
        ("cheapest", true, None),
        ("normal+crashes", false, Some(25 * MINUTE)),
        ("cheapest+crashes", true, Some(25 * MINUTE)),
    ] {
        let r = run(
            &c,
            &jobs,
            model(120.0),
            RunOptions {
                cheapest,
                crash_mttf: crash,
                seed: 31,
                max_sim_time: 3 * 24 * HOUR,
                ..Default::default()
            },
        );
        table.row(&[
            name.to_string(),
            r.makespan().map(fmt_dur).unwrap_or("-".into()),
            format!("{:.4}", r.cost.ec2_usd),
            format!("{:.4}", r.cost.total_usd()),
            r.stats.instances_launched.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: cheapest ≤ normal on cost, ≥ on makespan; gap widens with crashes (no replacement).");
}

/// T4 — visibility timeout trade-off, 4 seeds per point through the
/// sweep engine (duplicate counts are rare events; one seed lies).
fn t4() {
    println!("\n== T4: SQS visibility timeout sweep (mean job 120 s, 5% stalls, 4 seeds) ==");
    let axis: Vec<(SimTime, &str)> = vec![
        (30 * SECOND, "0.25x"),
        (MINUTE, "0.5x"),
        (2 * MINUTE, "1x"),
        (4 * MINUTE, "2x"),
        (8 * MINUTE, "4x"),
        (16 * MINUTE, "8x"),
        (48 * MINUTE, "24x"),
    ];
    let matrix = ScenarioMatrix {
        seeds: vec![41, 42, 43, 44],
        visibilities: axis.iter().map(|&(v, _)| v).collect(),
        models: vec![DurationModel {
            mean_s: 120.0,
            cv: 0.3,
            stall_prob: 0.05,
            ..Default::default()
        }],
        cluster_machines: vec![4],
        ..Default::default()
    };
    let jobs = JobSpec::plate("P", 48, 2, vec![]); // 96 jobs
    let report = sweep_report(
        cfg(4, 10 * MINUTE),
        jobs,
        matrix,
        RunOptions {
            max_sim_time: 3 * 24 * HOUR,
            ..Default::default()
        },
    );
    let mut table = Table::new(&[
        "visibility", "x mean", "drained", "makespan p50", "duplicates", "dup % mean", "cost $ mean",
    ]);
    for ((vis, label), s) in labelled(&axis, &report) {
        table.row(&[
            fmt_dur(*vis),
            label.to_string(),
            format!("{}/{}", s.drained, s.cells),
            s.makespan_cell(s.makespan_s.p50),
            s.duplicates.to_string(),
            format!("{:.1}", s.duplicate_rate.mean * 100.0),
            format!("{:.4}", s.cost_usd.mean),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: short -> duplicate-work waste; long -> stall recovery dominates makespan; sweet spot ~1-2x mean (paper: 'slightly longer than the average').");
}

/// T5 — interruption tolerance vs market volatility, 4 seeds per level
/// through the sweep engine.
fn t5() {
    println!("\n== T5: spot interruption tolerance (384 jobs, tight 10% bid headroom, 4 seeds) ==");
    let levels = [
        ("low", Volatility::Low),
        ("medium", Volatility::Medium),
        ("high", Volatility::High),
    ];
    let mut base = cfg(6, 10 * MINUTE);
    base.machine_price = 0.192 * 0.30 * 1.10;
    let matrix = ScenarioMatrix {
        seeds: vec![51, 52, 53, 54],
        volatilities: levels.iter().map(|&(_, v)| v).collect(),
        cluster_machines: vec![6],
        models: vec![model(240.0)],
        ..Default::default()
    };
    let jobs = JobSpec::plate("P", 96, 4, vec![]);
    let report = sweep_report(
        base,
        jobs,
        matrix,
        RunOptions {
            max_sim_time: 7 * 24 * HOUR,
            ..Default::default()
        },
    );
    let mut table = Table::new(&[
        "volatility", "drained", "interruptions", "completed", "duplicates", "lost-to-death", "makespan p50", "makespan p95",
    ]);
    for ((name, _), s) in labelled(&levels, &report) {
        table.row(&[
            name.to_string(),
            format!("{}/{}", s.drained, s.cells),
            s.interruptions.to_string(),
            format!("{}/{}", s.completed, s.jobs_submitted),
            s.duplicates.to_string(),
            s.lost_to_death.to_string(),
            s.makespan_cell(s.makespan_s.p50),
            s.makespan_cell(s.makespan_s.p95),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: completion stays 100% at every rate (SQS redelivery); waste and makespan grow with volatility.");
}

/// T6 — CHECK_IF_DONE resume.
fn t6() {
    println!("\n== T6: resume with CHECK_IF_DONE after a 50% crash (192 jobs) ==");
    use ds_rs::coordinator::run::Simulation;
    let c = cfg(6, 10 * MINUTE);
    let jobs = JobSpec::plate("P", 96, 2, vec![]);
    // Phase 1: interrupted run.
    let mut sim1 = Simulation::new(
        c.clone(),
        RunOptions {
            max_sim_time: 12 * MINUTE,
            ..Default::default()
        },
    )
    .unwrap();
    sim1.submit(&jobs).unwrap();
    sim1.start(&fleet_file()).unwrap();
    let mut ex = ModeledExecutor {
        model: model(120.0),
        ..Default::default()
    };
    let r1 = sim1.run(&mut ex).unwrap();
    let done_keys = sim1.acct.s3.list_prefix("ds-data", "output/");
    println!(
        "phase 1 (killed at 12 min): {}/{} jobs done, EC2 ${:.4}",
        r1.stats.completed, r1.jobs_submitted, r1.cost.ec2_usd
    );
    let mut table = Table::new(&["resume mode", "reran", "skipped", "makespan", "EC2 $"]);
    for enabled in [true, false] {
        let mut c2 = c.clone();
        c2.check_if_done.enabled = enabled;
        let mut sim2 = Simulation::new(c2, RunOptions::default()).unwrap();
        sim2.stage(|acct| {
            for (k, sz) in &done_keys {
                acct.s3
                    .put("ds-data", k, ds_rs::aws::s3::Body::Synthetic { size: *sz }, 0)
                    .unwrap();
            }
        });
        sim2.submit(&jobs).unwrap();
        sim2.start(&fleet_file()).unwrap();
        let mut ex2 = ModeledExecutor {
            model: model(120.0),
            ..Default::default()
        };
        let r2 = sim2.run(&mut ex2).unwrap();
        table.row(&[
            if enabled { "CHECK_IF_DONE=true" } else { "CHECK_IF_DONE=false" }.to_string(),
            r2.stats.completed.to_string(),
            r2.stats.skipped_done.to_string(),
            r2.makespan().map(fmt_dur).unwrap_or("-".into()),
            format!("{:.4}", r2.cost.ec2_usd),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: resume reruns only the missing fraction; disabled reruns (and pays for) everything.");
}

/// T7 — dead-letter queue bounds poison damage.
fn t7() {
    println!("\n== T7: poison jobs with and without an effective DLQ (1% poison) ==");
    let mut jobs = JobSpec::plate("P", 96, 2, vec![]); // 192 jobs
    for i in [17usize, 103] {
        jobs.groups[i].push(("poison".into(), Value::Bool(true)));
    }
    let mut table = Table::new(&[
        "max_receive", "completed", "dead-lettered", "cleaned up", "ended", "EC2 $",
    ]);
    for &(max_recv, label) in &[(5u32, "5 (DLQ works)"), (100_000, "∞ (no DLQ)")] {
        let mut c = cfg(4, 3 * MINUTE);
        c.max_receive_count = max_recv;
        let r = run(
            &c,
            &jobs,
            model(60.0),
            RunOptions {
                seed: 71,
                max_sim_time: 24 * HOUR,
                ..Default::default()
            },
        );
        table.row(&[
            label.to_string(),
            format!("{}/{}", r.stats.completed, r.jobs_submitted),
            r.stats.dead_lettered.to_string(),
            r.cleaned_up.to_string(),
            fmt_dur(r.ended_at),
            format!("{:.4}", r.cost.ec2_usd),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: with the DLQ the run ends shortly after the good work; without it the cluster idles+churns until the cap.");
}

/// T8 — crash reaper value.
fn t8() {
    println!("\n== T8: instance crashes vs the CPU<1%/15min alarm reaper (384 jobs) ==");
    let jobs = JobSpec::plate("P", 96, 4, vec![]);
    let mut table = Table::new(&[
        "crash MTTF", "crashes", "alarm-reaped", "completed", "makespan", "EC2 $",
    ]);
    for &(mttf, label) in &[
        (None, "none"),
        (Some(120 * MINUTE), "2h"),
        (Some(45 * MINUTE), "45m"),
        (Some(20 * MINUTE), "20m"),
    ] {
        let c = cfg(6, 10 * MINUTE);
        let r = run(
            &c,
            &jobs,
            model(150.0),
            RunOptions {
                seed: 81,
                crash_mttf: mttf,
                max_sim_time: 3 * 24 * HOUR,
                ..Default::default()
            },
        );
        table.row(&[
            label.to_string(),
            r.stats.crashes.to_string(),
            r.stats.alarm_terminations.to_string(),
            format!("{}/{}", r.stats.completed, r.jobs_submitted),
            r.makespan().map(fmt_dur).unwrap_or("-".into()),
            format!("{:.4}", r.cost.ec2_usd),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: every row completes 100%; makespan degrades gracefully because reaped machines are replaced.");
}

/// T9 — ECS placement mismatch matrix.
fn t9() {
    println!("\n== T9: ECS placement: containers placed per machine type ==");
    use ds_rs::aws::ecs::{Ecs, Service, TaskDefinition};
    let shapes = [
        ("1 vCPU/2GB", 1024u32, 2_048u64),
        ("2 vCPU/7.5GB", 2048, 7_680),
        ("4 vCPU/15GB", 4096, 15_360),
        ("8 vCPU/30GB", 8192, 30_720),
    ];
    let machines = ["m5.large", "m5.xlarge", "m5.2xlarge", "m5.4xlarge"];
    let mut table = Table::new(&["container \\ machine", "m5.large", "m5.xlarge", "m5.2xlarge", "m5.4xlarge"]);
    for (label, cpu, mem) in shapes {
        let mut row = vec![label.to_string()];
        for m in machines {
            let ty = ds_rs::aws::ec2::instance_type(m).unwrap();
            let mut ecs = Ecs::new();
            ecs.register_task_definition(TaskDefinition {
                family: "app".into(),
                cpu_shares: cpu,
                memory_mb: mem,
                env: vec![],
            });
            ecs.create_service(Service {
                name: "svc".into(),
                cluster: "default".into(),
                task_family: "app".into(),
                desired_count: 100,
            })
            .unwrap();
            ecs.register_instance("default", 1, ty.vcpus, ty.memory_mb).unwrap();
            row.push(ecs.place_tasks(0).len().to_string());
        }
        table.row(&row);
    }
    println!("{}", table.render());
    println!("shape check: 0 where the Docker exceeds the machine; over-large machines get over-packed (paper's caveat).");
}

/// T10 — bid headroom vs fleet fulfillment latency.
fn t10() {
    println!("\n== T10: bid headroom vs time-to-ready (50-machine fleet, quiet market) ==");
    use ds_rs::aws::ec2::{Ec2, FleetEvent, SpotFleetSpec, SpotMarket};
    use ds_rs::sim::SimRng;
    let base = 0.096 * 0.31;
    let mut table = Table::new(&["bid/base", "mean ready", "p95 ready", "unfulfilled"]);
    for &mult in &[1.02, 1.1, 1.25, 1.5, 2.0, 3.0] {
        let mut means = Vec::new();
        let mut unfulfilled = 0u32;
        for seed in 0..5u64 {
            let mut ec2 = Ec2::new(
                SpotMarket::new(900 + seed, Volatility::Low),
                SimRng::new(seed),
            );
            ec2.request_spot_fleet(SpotFleetSpec::homogeneous(50, base * mult, "m5.large"));
            for ev in ec2.evaluate_fleets(0) {
                match ev {
                    FleetEvent::InstanceRequested { ready_at, .. } => {
                        means.push(ready_at as f64)
                    }
                    FleetEvent::CapacityUnavailable { missing, .. } => unfulfilled += missing,
                    _ => {}
                }
            }
        }
        means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = means.iter().sum::<f64>() / means.len().max(1) as f64;
        let p95 = means.get((means.len() as f64 * 0.95) as usize).copied().unwrap_or(0.0);
        table.row(&[
            format!("{mult:.2}"),
            fmt_dur(mean as SimTime),
            fmt_dur(p95 as SimTime),
            unfulfilled.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: 'a couple of minutes to several hours' — tight bids wait, comfortable bids boot in ~1-2 min.");
}

/// T11 (ablation) — how to slice a machine: TASKS_PER_MACHINE x
/// DOCKER_CORES at constant total parallelism per machine.
fn t11() {
    println!("\n== T11 (ablation): tasks/machine x docker cores (4 vCPU machines, 384 jobs) ==");
    let jobs = JobSpec::plate("P", 96, 4, vec![]);
    let mut table = Table::new(&[
        "tasks x cores", "cpu/ctr", "mem/ctr MB", "makespan", "EC2 $", "notes",
    ]);
    for &(tasks, cores) in &[(1u32, 4u32), (2, 2), (4, 1), (2, 4), (1, 1)] {
        let cpu = 4096 / tasks;
        let mem = 15_000 / u64::from(tasks);
        let c = AppConfig {
            cluster_machines: 8,
            tasks_per_machine: tasks,
            docker_cores: cores,
            cpu_shares: cpu,
            memory_mb: mem,
            machine_types: vec!["m5.xlarge".into()],
            machine_price: 0.10,
            sqs_message_visibility: 10 * MINUTE,
            ..Default::default()
        };
        let r = run(&c, &jobs, model(90.0), RunOptions { seed: 61, ..Default::default() });
        let note = if tasks * cores > 4 {
            "oversubscribed"
        } else if tasks * cores < 4 {
            "undersubscribed"
        } else {
            "matched"
        };
        table.row(&[
            format!("{tasks} x {cores}"),
            cpu.to_string(),
            mem.to_string(),
            r.makespan().map(fmt_dur).unwrap_or("-".into()),
            format!("{:.4}", r.cost.ec2_usd),
            note.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: any slicing that matches total cores performs alike; undersubscription wastes the machine (cost up, speed down).");
}

/// T12 — allocation strategies on a heterogeneous fleet under T5's
/// volatility grid: does diversification buy interruption tolerance, and
/// at what price?
fn t12() {
    use ds_rs::aws::ec2::{AllocationStrategy, InstanceSlot};
    println!("\n== T12: allocation strategy x volatility (4-pool fleet, tight per-unit bid, 4 seeds) ==");
    let vols = [
        ("low", Volatility::Low),
        ("medium", Volatility::Medium),
        ("high", Volatility::High),
    ];
    let strategies = AllocationStrategy::ALL;
    // Four pools, weighted so one per-unit bid is tight (~1.1-1.2x base)
    // everywhere: per-unit spot bases 0.0298 / 0.0288 / 0.0272 / 0.0269.
    let set: Vec<InstanceSlot> = ["m5.large", "m5.xlarge:2", "c5.xlarge:2", "r5.xlarge:3"]
        .iter()
        .map(|s| InstanceSlot::parse(s).unwrap())
        .collect();
    let mut base = cfg(8, 10 * MINUTE);
    base.machine_price = 0.033; // per weighted unit
    // Scenario API v2: the fluent builder replaces the struct literal —
    // unset axes inherit the config-aware defaults.
    let plan = SweepPlan::builder()
        .config(base)
        .jobs(JobSpec::plate("P", 96, 4, vec![])) // 384 jobs
        .options(RunOptions {
            max_sim_time: 7 * 24 * HOUR,
            ..Default::default()
        })
        .seeds([121, 122, 123, 124])
        .volatilities(vols.iter().map(|&(_, v)| v))
        .allocations(strategies.iter().copied())
        .instance_sets([set])
        .models([model(240.0)])
        .build()
        .expect("T12 plan");
    let report = run_sweep(&plan, default_threads()).expect("sweep failed").report;
    // Scenario order: volatility outer, allocation inner.
    let axis: Vec<(&str, &str)> = vols
        .iter()
        .flat_map(|&(vn, _)| strategies.iter().map(move |a| (vn, a.name())))
        .collect();
    let mut table = Table::new(&[
        "volatility", "allocation", "drained", "interruptions", "lost-to-death", "duplicates",
        "pools hit", "makespan p50", "cost $ mean",
    ]);
    for ((vol, alloc), s) in labelled(&axis, &report) {
        let pools_hit = s.pools.iter().filter(|p| p.interrupted > 0).count();
        table.row(&[
            vol.to_string(),
            alloc.to_string(),
            format!("{}/{}", s.drained, s.cells),
            s.interruptions.to_string(),
            s.lost_to_death.to_string(),
            s.duplicates.to_string(),
            pools_hit.to_string(),
            s.makespan_cell(s.makespan_s.p50),
            format!("{:.4}", s.cost_usd.mean),
        ]);
    }
    println!("{}", table.render());
    println!("shape check: lowest-price concentrates in one pool, so a single spike interrupts the whole fleet at once \
              (high lost-to-death); diversified spreads the same capacity over all four pools and loses less work under \
              high volatility at comparable cost; capacity-optimized sits between.");
}

/// T13 — compute-bound → storage-bound: throughput vs CLUSTER_MACHINES
/// at a fixed per-job data footprint on a narrow (1 Gbit/s) bucket.
/// Doubling machines stops helping once the fleet's aggregate byte
/// demand exceeds the bucket's throughput — the knee — and the
/// bottleneck attribution column says *why* (bucket-bound share of
/// constrained flow time → ~100%).
fn t13() {
    println!("\n== T13: storage-bound knee (384 jobs, 256 MB in / ~32 MB out, narrow bucket, 2 seeds) ==");
    let machine_axis = vec![2u32, 4, 8, 16, 32];
    let input_mb = 256.0;
    let mean_s = 90.0;
    let profile = NetProfile::narrow();
    let plan = SweepPlan::builder()
        .config(cfg(1, 10 * MINUTE))
        .jobs(JobSpec::plate("P", 48, 8, vec![])) // 384 jobs
        .options(RunOptions {
            max_sim_time: 3 * 24 * HOUR,
            ..Default::default()
        })
        .seeds([131, 132])
        .machines(machine_axis.iter().copied())
        .input_mbs([input_mb])
        .net_profiles([profile.clone()])
        .models([model(mean_s)])
        .build()
        .expect("T13 plan");
    let report = run_sweep(&plan, default_threads()).expect("sweep failed").report;
    // Bucket ceiling in jobs/h: every job moves ~input + input/8 bytes
    // through the one bucket.
    let bytes_per_job = input_mb * 1e6 * (1.0 + 1.0 / 8.0);
    let bucket_ceiling = profile.bucket_bytes_per_ms() * 1000.0 * 3600.0 / bytes_per_job;
    let mut table = Table::new(&[
        "machines", "drained", "makespan p50", "jobs/h", "compute ideal", "bucket ceiling",
        "bucket-bound %", "GB moved", "GB wasted", "egress $",
    ]);
    for (m, s) in labelled(&machine_axis, &report) {
        let ideal = f64::from(m * 4) * 3600.0 / mean_s;
        table.row(&[
            m.to_string(),
            format!("{}/{}", s.drained, s.cells),
            s.makespan_cell(s.makespan_s.p50),
            format!("{:.0}", s.jobs_per_hour.mean),
            format!("{ideal:.0}"),
            format!("{bucket_ceiling:.0}"),
            format!("{:.0}", s.data.bucket_bound_fraction() * 100.0),
            format!("{:.1}", s.data.total_bytes() as f64 / 1e9),
            format!("{:.1}", s.data.bytes_wasted as f64 / 1e9),
            format!("{:.4}", s.data.egress_usd),
        ]);
    }
    println!("{}", table.render());
    println!(
        "shape check: jobs/h tracks min(compute ideal, bucket ceiling): linear while compute-bound, \
         flat past the knee where the *bucket* (not the fleet) is the bottleneck — the bucket-bound \
         column pins the attribution."
    );
}


/// T14 — closed-loop elastic autoscaling under bursty arrivals: the
/// cost × makespan frontier of a fixed peak-size fleet vs the
/// target-tracking and step policies.  Waves of jobs arrive with idle
/// gaps between them; the fixed fleet churns replacement machines
/// through every gap (self-shutdown → relaunch toward target), while
/// the autoscaler shrinks to its floor and grows back through the
/// backlog alarms when the next wave lands.
fn t14() {
    println!("\n== T14: autoscaling under bursty arrivals (6 waves x 64 jobs, 20 min gaps, max 8 machines, 3 seeds) ==");
    let policies: [(&str, Option<ScalingPolicy>); 3] = [
        ("fixed", None),
        ("target-tracking", Some(ScalingPolicy::target_tracking(3.0))),
        ("step", Some(ScalingPolicy::step(3.0))),
    ];
    let seeds = [141u64, 142, 143];
    let waves = 6u64;
    let wave_gap_min = 20u64;
    let mut table = Table::new(&[
        "policy", "makespan p95", "cost $ mean", "vs fixed", "decisions", "out/in",
        "capacity", "unit-h mean", "launched",
    ]);
    let mut fixed_cost_mean = 0.0;
    for (name, policy) in &policies {
        let mut makespans = Vec::new();
        let mut costs = Vec::new();
        let mut decisions = 0u64;
        let mut outs = 0u64;
        let mut ins = 0u64;
        let mut launched = 0u64;
        let mut unit_h = Vec::new();
        let mut peak = 0u32;
        let mut floor = u32::MAX;
        for &seed in &seeds {
            let opts = RunOptions {
                seed,
                scaling: policy.clone(),
                max_sim_time: 24 * HOUR,
                ..Default::default()
            };
            let mut sim = Simulation::new(cfg(8, 10 * MINUTE), opts).expect("sim");
            let wave = || JobSpec::plate("P", 32, 2, vec![]); // 64 jobs
            sim.submit(&wave()).unwrap();
            for k in 1..waves {
                sim.submit_at(k * wave_gap_min * MINUTE, wave());
            }
            sim.start(&fleet_file()).unwrap();
            let mut ex = ModeledExecutor {
                model: model(90.0),
                ..Default::default()
            };
            let r = sim.run(&mut ex).expect("run");
            assert!(r.fully_accounted(), "{}", r.summary());
            makespans.push(r.drained_at.expect("drained") as f64 / 1000.0);
            costs.push(r.cost.total_usd());
            decisions += r.scaling.decisions;
            outs += r.scaling.scale_outs;
            ins += r.scaling.scale_ins;
            launched += r.stats.instances_launched;
            unit_h.push(r.scaling.capacity_unit_hours);
            // The fixed fleet's "none" breakdown reports zero capacity
            // bounds; substitute its actual constant size.
            peak = peak.max(if r.scaling.policy == "none" {
                8
            } else {
                r.scaling.peak_capacity
            });
            floor = floor.min(if r.scaling.policy == "none" {
                8
            } else {
                r.scaling.floor_capacity
            });
        }
        let mk = Aggregate::from_values(&makespans);
        let cost = Aggregate::from_values(&costs);
        let uh = Aggregate::from_values(&unit_h);
        if *name == "fixed" {
            fixed_cost_mean = cost.mean;
        }
        table.row(&[
            name.to_string(),
            fmt_dur((mk.p95 * 1000.0) as SimTime),
            format!("{:.4}", cost.mean),
            format!("{:.2}x", cost.mean / fixed_cost_mean.max(1e-12)),
            decisions.to_string(),
            format!("{outs}/{ins}"),
            format!("{}..{}", if floor == u32::MAX { 8 } else { floor }, peak),
            format!("{:.2}", uh.mean),
            launched.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "shape check: both policies complete every wave; target-tracking holds p95 makespan at the \
         fixed fleet's level (the backlog alarm re-grows the fleet within a couple of minutes of a \
         wave landing, about the fixed fleet's own churn-boot lag) while paying far less for the idle \
         gaps — the fixed fleet relaunches its whole peak through every gap, the autoscaler idles at \
         its floor.  Step ramps instead of jumping, so it sits between."
    );
}

/// T15 — the data-sharing frontier: workflow shape × artifact sharing
/// mode.  Every DAG runs under all three sharing modes; S3 staging pays
/// request + egress costs for every intermediate artifact, node-local
/// pulls straight from the producer's NIC (no bucket, no egress), and a
/// shared filesystem sits between (one shared link, no egress).  The
/// interesting read is the cost × makespan frontier per shape: how much
/// of the staging bill the topology lets each mode avoid, and what the
/// dependency stalls cost in wall-clock.
fn t15() {
    use ds_rs::workflow::SharingMode;
    use ds_rs::workloads::dag;
    println!("\n== T15: workflow data-sharing frontier (shape x sharing mode, 3 seeds) ==");
    let shapes = [dag::diamond(), dag::fan_out_in(), dag::linear(), dag::mosaic()];
    let sharings = SharingMode::ALL;
    let plan = SweepPlan::builder()
        .config(cfg(4, 10 * MINUTE))
        // Workflow cells ignore the Job file: the DAG is the workload.
        .jobs(JobSpec::plate("P", 2, 1, vec![]))
        .options(RunOptions {
            max_sim_time: 24 * HOUR,
            ..Default::default()
        })
        .seeds([151, 152, 153])
        .workflows(shapes.iter().cloned().map(Some))
        .sharings(sharings.iter().copied())
        .models([model(120.0)])
        .build()
        .expect("T15 plan");
    let report = run_sweep(&plan, default_threads()).expect("sweep failed").report;
    // Scenario order: workflow outer, sharing inner.
    let axis: Vec<(String, &str)> = shapes
        .iter()
        .flat_map(|w| sharings.iter().map(move |s| (w.name.clone(), s.name())))
        .collect();
    let mut table = Table::new(&[
        "workflow", "sharing", "drained", "stages", "makespan p50", "stall/cell",
        "GB staged", "egress $", "cost $ mean",
    ]);
    for ((wf, share), s) in labelled(&axis, &report) {
        let cells = s.cells.max(1) as f64;
        table.row(&[
            wf.clone(),
            share.to_string(),
            format!("{}/{}", s.drained, s.cells),
            s.workflow.critical_path_len.to_string(),
            s.makespan_cell(s.makespan_s.p50),
            fmt_dur((s.workflow.stall_ms as f64 / cells) as SimTime),
            format!("{:.2}", s.workflow.artifact_bytes_staged as f64 / 1e9),
            format!("{:.4}", s.data.egress_usd),
            format!("{:.4}", s.cost_usd.mean),
        ]);
    }
    println!("{}", table.render());
    println!(
        "shape check: node-local erases the egress bill and most of the staged bytes for every shape; \
         the win scales with intermediate-artifact volume (mosaic > diamond > linear), while the \
         critical path — and so the stall floor — is a property of the shape, not the sharing mode."
    );
}

/// T16 — the correlated-failure trade-off: placement policy × AZ-outage
/// severity over the two-region topology.  Pack keeps every machine in
/// the home AZ (no egress, maximal blast radius); spread round-robins
/// across regions, so capacity survives the home AZ going dark — at the
/// price of cross-region egress from the remote domain, itemized in the
/// topology breakdown.  The outage always hits the home AZ at t=0.
fn t16() {
    use ds_rs::topology::{ClusterTopology, FaultKind, Placement};
    println!(
        "\n== T16: multi-region survivability (placement x AZ-outage severity, two-region, 3 seeds) =="
    );
    let severities: [(&str, Option<u64>); 3] =
        [("none", None), ("1h", Some(60)), ("whole-run", Some(24 * 60))];
    let topologies: Vec<Option<ClusterTopology>> = severities
        .iter()
        .map(|(_, dur)| {
            let b = ClusterTopology::builder("two-region")
                .domain("us-east-1a", "us-east-1")
                .domain("us-west-2a", "us-west-2");
            let b = match dur {
                Some(d) => b.fault(FaultKind::AzOutage, "us-east-1a", 0, *d, 1.0),
                None => b,
            };
            Some(b.build().expect("T16 topology"))
        })
        .collect();
    let placements = [Placement::Pack, Placement::Spread];
    let plan = SweepPlan::builder()
        .config(cfg(4, 10 * MINUTE))
        // 32 data-shaped jobs, so remote-domain machines meter egress.
        .jobs(JobSpec::plate("P", 16, 2, vec![]).with_uniform_data(64_000_000, 8_000_000))
        .options(RunOptions {
            max_sim_time: 8 * HOUR,
            ..Default::default()
        })
        .seeds([161, 162, 163])
        .topologies(topologies)
        .placements(placements.iter().copied())
        .models([model(120.0)])
        .build()
        .expect("T16 plan");
    let report = run_sweep(&plan, default_threads()).expect("sweep failed").report;
    // Scenario order: topology outer, placement inner.
    let axis: Vec<(&str, &str)> = severities
        .iter()
        .flat_map(|(sev, _)| placements.iter().map(move |p| (*sev, p.name())))
        .collect();
    let mut table = Table::new(&[
        "outage", "placement", "drained", "jobs done", "interrupted", "x-region GB",
        "x-region $", "cost $ mean",
    ]);
    let mut done = std::collections::BTreeMap::new();
    for ((sev, place), s) in labelled(&axis, &report) {
        done.insert((*sev, *place), (s.completed, s.topology.xregion_usd));
        table.row(&[
            sev.to_string(),
            place.to_string(),
            format!("{}/{}", s.drained, s.cells),
            s.completed.to_string(),
            s.interruptions.to_string(),
            format!("{:.2}", s.topology.xregion_bytes as f64 / 1e9),
            format!("{:.4}", s.topology.xregion_usd),
            format!("{:.4}", s.cost_usd.mean),
        ]);
    }
    println!("{}", table.render());
    // The acceptance shape: under the whole-run outage spread completes
    // strictly more jobs than pack, and its premium is itemized as
    // cross-region egress.
    let (pack_done, _) = done[&("whole-run", "pack")];
    let (spread_done, spread_xregion) = done[&("whole-run", "spread")];
    assert!(
        spread_done > pack_done,
        "spread must out-survive pack under the outage ({spread_done} vs {pack_done})"
    );
    assert!(
        spread_xregion > 0.0,
        "spread's survivability premium must surface as cross-region egress"
    );
    println!(
        "shape check: with no outage, pack is strictly cheaper (zero cross-region egress) at the \
         same throughput; as the outage window grows, pack's home-AZ fleet goes dark with it — under \
         the whole-run outage the pure-spot pack fleet completes nothing — while spread keeps half \
         its capacity in the surviving region and finishes the plate, paying for the privilege in \
         itemized cross-region egress dollars."
    );
}

/// T17 — multi-tenant fairness under a noisy neighbor: queueing policy
/// × an open-loop heavy-tailed flood on an elastic fleet.  A small
/// interactive tenant ("victim") trickles jobs in at a steady Poisson
/// rate while a batch tenant ("noisy") dumps Pareto bursts of dozens of
/// jobs at once.  Under FIFO every burst lands in front of whatever the
/// victim submits next; fair-share (WDRR) interleaves the tenants at
/// the dispatch layer, and strict priority serves the victim first
/// outright.  The autoscaler sees only the aggregate backlog, so the
/// plant is identical across policies — the wait gap is pure queueing
/// discipline.
fn t17() {
    use ds_rs::coordinator::autoscale::ScalingMode;
    use ds_rs::traffic::{QueueingPolicy, TenantSlice, TrafficSpec};
    println!(
        "\n== T17: fair-share vs FIFO under a heavy-tailed noisy neighbor (elastic fleet, 2 seeds) =="
    );
    let crunch = TrafficSpec::builder("crunch")
        .tenant("victim", 12, 1, 1, 300)
        .tenant("noisy", 150, 1, 0, 3600)
        .poisson("victim", 1.0)
        .heavy_tailed("noisy", 1.2, 0.02)
        .build()
        .expect("T17 traffic");
    let policies = QueueingPolicy::ALL;
    let plan = SweepPlan::builder()
        .config(cfg(6, 10 * MINUTE))
        // Traffic cells ignore the Job file: the generators are the
        // workload.
        .jobs(JobSpec::plate("P", 2, 1, vec![]))
        .options(RunOptions {
            max_sim_time: 8 * HOUR,
            ..Default::default()
        })
        .seeds([171, 172])
        .scalings([ScalingMode::TargetTracking])
        .scaling_targets([3.0])
        .traffics([Some(crunch)])
        .queueings(policies)
        .models([model(90.0)])
        .build()
        .expect("T17 plan");
    let report = run_sweep(&plan, default_threads()).expect("sweep failed").report;
    let tenant = |s: &ScenarioSummary, name: &str| -> TenantSlice {
        s.traffic
            .tenants
            .iter()
            .find(|t| t.tenant == name)
            .unwrap_or_else(|| panic!("no tenant '{name}' in '{}'", s.label))
            .clone()
    };
    let mut table = Table::new(&[
        "queueing", "drained", "victim wait p50", "victim wait p95", "victim SLO",
        "noisy wait p95", "makespan p50", "cost $ mean",
    ]);
    let mut victim_p95 = std::collections::BTreeMap::new();
    for (policy, s) in labelled(&policies, &report) {
        let v = tenant(s, "victim");
        let n = tenant(s, "noisy");
        victim_p95.insert(policy.name(), (v.clone(), s.completed));
        table.row(&[
            policy.name().to_string(),
            format!("{}/{}", s.drained, s.cells),
            fmt_dur(v.wait_p50_ms),
            fmt_dur(v.wait_p95_ms),
            format!("{}/{}", v.slo_attained, v.completed),
            fmt_dur(n.wait_p95_ms),
            s.makespan_cell(s.makespan_s.p50),
            format!("{:.4}", s.cost_usd.mean),
        ]);
    }
    println!("{}", table.render());
    // The acceptance shape: every policy finishes both tenants' work,
    // and fair-share bounds the victim's p95 wait strictly below
    // FIFO's — the noisy neighbor can no longer starve the victim.
    let (fifo_victim, fifo_done) = &victim_p95["fifo"];
    let (fair_victim, fair_done) = &victim_p95["fair-share"];
    let per_seed_jobs: u64 = 12 + 150;
    assert_eq!(*fifo_done, per_seed_jobs * 2, "fifo must complete every job");
    assert_eq!(*fair_done, per_seed_jobs * 2, "fair-share must complete every job");
    assert!(
        fair_victim.wait_p95_ms < fifo_victim.wait_p95_ms,
        "fair-share must bound the victim's p95 wait below FIFO's \
         ({} vs {})",
        fmt_dur(fair_victim.wait_p95_ms),
        fmt_dur(fifo_victim.wait_p95_ms),
    );
    assert!(
        fair_victim.slo_attained >= fifo_victim.slo_attained,
        "fair-share must not lose SLO ground to FIFO ({} vs {})",
        fair_victim.slo_attained,
        fifo_victim.slo_attained,
    );
    println!(
        "shape check: the plant (fleet, autoscaler, job mix) is identical in every row — only the \
         dispatch order changes.  FIFO lets each Pareto burst queue ahead of the victim's next \
         arrival, inflating its p95 wait and SLO misses; fair-share interleaves the two tenants \
         regardless of burst depth, and strict priority drives the victim's wait to the floor at \
         the noisy tenant's expense."
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let all = args.is_empty() || args.iter().any(|a| a == "all");
    let want = |name: &str| all || args.iter().any(|a| a == name);
    if want("t1") {
        t1();
    }
    if want("t2") {
        t2();
    }
    if want("t3") {
        t3();
    }
    if want("t4") {
        t4();
    }
    if want("t5") {
        t5();
    }
    if want("t6") {
        t6();
    }
    if want("t7") {
        t7();
    }
    if want("t8") {
        t8();
    }
    if want("t9") {
        t9();
    }
    if want("t10") {
        t10();
    }
    if want("t11") {
        t11();
    }
    if want("t12") {
        t12();
    }
    if want("t13") {
        t13();
    }
    if want("t14") {
        t14();
    }
    if want("t15") {
        t15();
    }
    if want("t16") {
        t16();
    }
    if want("t17") {
        t17();
    }
}
