#!/usr/bin/env bash
# Perf harness for the event core (DESIGN.md §"Event core").
#
# Builds and runs the event_core bench (queue/store micro-benches plus
# the macro-scale simulation), compares the result against the committed
# BENCH_*.json snapshot, and rewrites the snapshot with the fresh
# numbers.  Exits non-zero when macro throughput (jobs/s) drops below
# 80% of the baseline for the same mode — the CI bench lane runs
# `--smoke` on every push.
#
#   ./benchmark_compare.sh            # smoke macro (10^5 jobs / 500 machines)
#   ./benchmark_compare.sh --million  # full 10^6 jobs / 10^3 machines
#   ./benchmark_compare.sh --shards   # sharded sweep across 1/2/4/8 workers
#
# The event-core snapshot keeps one macro section per mode (smoke /
# million); a run only overwrites its own mode's section, so the
# committed million number survives smoke runs.  `--shards` runs the
# sweep bench's sharded-dispatch mode instead (real `ds shard-worker`
# processes) and diffs per-shard-count throughput against BENCH_7.json.
# Baselines whose matching section is null or that carry
# `"unmeasured": true` (bootstrap snapshots committed before a machine
# ever ran the bench) are recorded, not compared.

set -euo pipefail

cd "$(dirname "$0")"

MODE=smoke
for arg in "$@"; do
  case "$arg" in
    --smoke) MODE=smoke ;;
    --million) MODE=million ;;
    --shards) MODE=shards ;;
    *)
      echo "usage: $0 [--smoke|--million|--shards]" >&2
      exit 2
      ;;
  esac
done

if [ "$MODE" = shards ]; then
  SNAPSHOT="${BENCH_SHARD_SNAPSHOT:-BENCH_7.json}"
  echo "==> cargo bench --bench sweep (--shards)" >&2
  RESULT=$(cargo bench --manifest-path rust/Cargo.toml --bench sweep -- --shards --json | tail -n 1)

  NEW_JSON="$RESULT" python3 - "$SNAPSHOT" <<'PY'
import json
import os
import sys

snapshot = sys.argv[1]
new = json.loads(os.environ["NEW_JSON"])

baseline = None
if os.path.exists(snapshot):
    try:
        with open(snapshot) as f:
            baseline = json.load(f)
    except ValueError:
        print(f"!! existing {snapshot} is not valid JSON; ignoring baseline",
              file=sys.stderr)
if not isinstance(baseline, dict):
    baseline = {}

THRESHOLD = 0.80
failed = False
old_tp = baseline.get("shard_throughput") or {}
new_tp = new.get("shard_throughput") or {}
if baseline.get("unmeasured"):
    print("== baseline is an unmeasured bootstrap snapshot: recording "
          "first real measurement", file=sys.stderr)
else:
    for shards in sorted(new_tp, key=int):
        old_v = old_tp.get(shards) or 0
        new_v = new_tp.get(shards) or 0
        if old_v > 0 and new_v > 0:
            ratio = new_v / old_v
            print(f"== shard_throughput[{shards}]: {old_v:.0f} -> {new_v:.0f} "
                  f"sim jobs/s ({ratio:.1%} of baseline)", file=sys.stderr)
            if ratio < THRESHOLD:
                print(f"!! regression at {shards} shards: {ratio:.1%} < "
                      f"{THRESHOLD:.0%} of baseline", file=sys.stderr)
                failed = True
        else:
            print(f"== no measured baseline at {shards} shards: recording "
                  "first measurement", file=sys.stderr)

merged = {
    "bench": "sweep_shards",
    "cells": new.get("cells"),
    "jobs_per_cell": new.get("jobs_per_cell"),
    "shard_throughput": new_tp,
}
with open(snapshot, "w") as f:
    json.dump(merged, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"== wrote {snapshot}", file=sys.stderr)
sys.exit(1 if failed else 0)
PY
  exit $?
fi

SNAPSHOT="${BENCH_SNAPSHOT:-BENCH_6.json}"

BENCH_ARGS=(--json)
if [ "$MODE" = million ]; then
  BENCH_ARGS+=(--million)
fi

echo "==> cargo bench --bench event_core ($MODE)" >&2
RESULT=$(cargo bench --manifest-path rust/Cargo.toml --bench event_core -- "${BENCH_ARGS[@]}" | tail -n 1)

NEW_JSON="$RESULT" python3 - "$SNAPSHOT" <<'PY'
import json
import os
import sys

snapshot = sys.argv[1]
new = json.loads(os.environ["NEW_JSON"])
mode = new.get("mode") or "smoke"

baseline = None
if os.path.exists(snapshot):
    try:
        with open(snapshot) as f:
            baseline = json.load(f)
    except ValueError:
        print(f"!! existing {snapshot} is not valid JSON; ignoring baseline",
              file=sys.stderr)
if not isinstance(baseline, dict):
    baseline = {}

base_macro = (baseline.get("macro") or {}).get(mode) or {}
old_tp = base_macro.get("jobs_per_s") or 0
new_tp = (new.get("macro") or {}).get("jobs_per_s") or 0

THRESHOLD = 0.80
failed = False
if baseline.get("unmeasured"):
    print("== baseline is an unmeasured bootstrap snapshot: recording "
          "first real measurement", file=sys.stderr)
elif old_tp > 0 and new_tp > 0:
    ratio = new_tp / old_tp
    print(f"== macro[{mode}] throughput: {old_tp:.0f} -> {new_tp:.0f} jobs/s "
          f"({ratio:.1%} of baseline)", file=sys.stderr)
    if ratio < THRESHOLD:
        print(f"!! regression: {ratio:.1%} < {THRESHOLD:.0%} of baseline",
              file=sys.stderr)
        failed = True
else:
    print(f"== no measured {mode} baseline: recording first measurement",
          file=sys.stderr)

# Informational only: micro-bench movement.
for section, key in (("queue_ops_per_s", "calendar"),
                     ("queue_ops_per_s", "heap"),
                     ("store_lookups_per_s", "dense"),
                     ("store_lookups_per_s", "map")):
    old_v = (baseline.get(section) or {}).get(key) or 0
    new_v = (new.get(section) or {}).get(key) or 0
    if old_v > 0 and new_v > 0:
        print(f"   {section}.{key}: {old_v:.0f} -> {new_v:.0f} "
              f"({new_v / old_v:.1%})", file=sys.stderr)

merged = dict(baseline)
merged.pop("unmeasured", None)
merged.pop("note", None)
merged.pop("mode", None)
merged["bench"] = "event_core"
for k in ("queue_ops_per_s", "store_lookups_per_s"):
    merged[k] = new.get(k)
macro = merged.get("macro")
if not isinstance(macro, dict) or "jobs_per_s" in macro:
    # Flat / legacy macro section: start the per-mode layout fresh.
    macro = {"smoke": None, "million": None}
macro[mode] = new.get("macro")
merged["macro"] = macro

with open(snapshot, "w") as f:
    json.dump(merged, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"== wrote {snapshot} (macro[{mode}] updated)", file=sys.stderr)
sys.exit(1 if failed else 0)
PY
